"""Lowering pass: Network -> megakernel scratch layout + firing table.

The megakernel backend runs a whole accelerated subnetwork as ONE
persistent Pallas kernel (paper §3.3 made literal): every Eq. 1 FIFO ring
buffer lives in device scratch memory for the kernel's entire lifetime,
and the token-driven sweep loop — the part the paper keeps resident on the
device instead of round-tripping dispatch decisions through the host —
runs *inside* the kernel.  This module is the build-time half: it flattens
the validated :class:`~repro.core.network.Network` into the static tables
the kernel body is traced from.

Outputs of :func:`lower_network`:

  * **scratch layout** — one ring-buffer scratch allocation per channel,
    shaped ``(capacity_tokens, *token_shape)`` straight from the Eq. 1
    capacity law (``FifoSpec.capacity_tokens``), plus one packed
    ``(n_fifos, 3)`` int32 cursor block (rd / wr / occ per channel, the
    kernel's register-resident analogue of ``FifoState``'s scalars);
  * **firing table** — one :class:`FiringRow` per actor in network
    declaration order (the same visit order as the token-driven host
    scheduler, so sweep counts and final states match bit for bit), each
    row resolving the actor's control / input / output ports to flat
    channel indices at build time so the traced kernel never touches a
    name-keyed dict;
  * reused analyses — ``Network.register_fifos`` (channels the static
    specializer proves transient; :func:`partition_layout` promotes the
    core-private subset of them to **in-kernel forwarding**: their rings
    become loop-carried token windows instead of scratch allocations, see
    ``kernel.py``) and :func:`~repro.core.schedule.phase_unroll_period`
    (the unroll period a static in-kernel prologue would use; recorded
    for the stats table and the ROADMAP follow-on, not yet acted on).

Grid partitioning (:func:`partition_layout`) additionally classifies each
channel as core-private or :data:`SHARED` and, by default, picks the
actor-to-core cut with the **crossing-bytes objective**: among contiguous
cuts of the visit order whose ``cost_flops`` bottleneck stays within
:data:`_CUT_BALANCE_SLACK` of the optimum, minimize the ring bytes of
partition-crossing channels — keeping fork/adder fan-outs core-local so
their rings stay private (and their transient subset stays forwardable).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Mapping, Optional, Tuple

import jax
import numpy as np

from repro.core.fifo import FifoSpec
from repro.core.network import Network
from repro.core.schedule import phase_unroll_period

# One packed cursor row per channel: (rd, wr, occ) int32.
CURSOR_FIELDS = 3
_CURSOR_ITEMSIZE = 4

#: ``GridPartition.fifo_cores`` value for a partition-crossing channel:
#: its ring lives in the shared block and its cursor row acts as the
#: cross-core semaphore (monotonic rd/wr counters polled in-kernel).
SHARED = -1

#: Partition-cut objectives accepted by :func:`partition_layout` /
#: :func:`default_assignment`.  ``"crossing"`` (default) minimizes the
#: ring bytes of partition-crossing channels among contiguous cuts whose
#: ``cost_flops`` bottleneck stays within :data:`_CUT_BALANCE_SLACK` of
#: the flops-only optimum; ``"flops"`` is the legacy pure load-balance
#: cut (linear-partition DP over ``cost_flops`` alone); ``"profile"``
#: runs the same crossing DP over *measured* weights — per-actor firing
#: load and per-channel occupancy churn from a traced run
#: (``repro.core.trace.Profile.as_cut_weights()``) — instead of static
#: ``cost_flops`` / capacity bytes.
CUT_OBJECTIVES = ("crossing", "flops", "profile")

#: How far above the flops-only optimal bottleneck the crossing-bytes
#: cut may trade load balance for locality.  1.25 keeps every core
#: within 25% of the best achievable max-load while letting the cut
#: move off a fan-out boundary (measured on DPD: the flops cut lands
#: mid-fork and shares 23 of 34 channels at 4 cores).
_CUT_BALANCE_SLACK = 1.25


@dataclasses.dataclass(frozen=True)
class PortBinding:
    """One regular port resolved to its flat channel index."""

    port: str
    fifo: int


@dataclasses.dataclass(frozen=True)
class FiringRow:
    """One actor's row in the firing table.

    ``control`` is the flat index of the control channel (None for static
    actors); ``inputs`` / ``outputs`` are the regular ports in declaration
    order — the same order ``fire_actor`` consumes them, which the kernel
    must preserve for bit-identical cursor arithmetic.
    """

    name: str
    index: int
    control: Optional[int]
    inputs: Tuple[PortBinding, ...]
    outputs: Tuple[PortBinding, ...]
    is_dynamic: bool
    has_ready: bool


@dataclasses.dataclass(frozen=True)
class MegakernelLayout:
    """Static layout of one lowered network (everything the kernel trace
    needs, nothing resolved per sweep)."""

    fifo_names: Tuple[str, ...]
    fifo_specs: Tuple[FifoSpec, ...]
    firing_table: Tuple[FiringRow, ...]
    # Channels the specialized static executor would register-allocate
    # (Network.register_fifos).  partition_layout promotes the
    # core-private subset to in-kernel forwarding
    # (GridPartition.forwarded_fifos): loop-carried token windows, zero
    # ring scratch; crossing transients stay semaphore-guarded rings.
    transient_fifos: frozenset
    # phase_unroll_period over the buffered channels — the unroll a static
    # in-kernel prologue would use (ROADMAP follow-on; diagnostic today).
    unroll_period: int

    # -- scratch accounting (the paper's Table 1, device-side) ---------- #
    @property
    def ring_scratch_bytes(self) -> int:
        """Eq. 1 capacities summed — bytes of ring buffer held in scratch."""
        return sum(s.capacity_bytes for s in self.fifo_specs)

    @property
    def cursor_bytes(self) -> int:
        return len(self.fifo_specs) * CURSOR_FIELDS * _CURSOR_ITEMSIZE

    @property
    def scratch_bytes(self) -> int:
        return self.ring_scratch_bytes + self.cursor_bytes

    @property
    def transient_scratch_bytes(self) -> int:
        """Ring bytes of the transient channels — the upper bound on what
        forwarding reclaims (``GridPartition.reclaimed_ring_bytes`` is
        the realized cut-dependent value: crossing transients stay
        buffered)."""
        return sum(s.capacity_bytes for s in self.fifo_specs
                   if s.name in self.transient_fifos)

    def scratch_shape(self, fifo_index: int) -> Tuple[int, ...]:
        """Ring scratch shape of one channel: Eq. 1 capacity x token."""
        spec = self.fifo_specs[fifo_index]
        return (spec.capacity_tokens,) + tuple(spec.token_shape)


def lower_network(network: Network) -> MegakernelLayout:
    """Flatten a validated network into the megakernel's static tables.

    Pure build-time work: reuses the port->spec tables the network
    precomputes (``in_port_specs`` / ``out_port_specs`` /
    ``control_specs``) and the ``register_fifos`` / phase-cycle analyses,
    so lowering adds no per-run cost and no new validation rules — any
    network the dynamic executor accepts lowers.
    """
    fifo_names = tuple(network.fifos)
    fifo_specs = tuple(network.fifos[n] for n in fifo_names)
    rows = []
    for index, (name, actor) in enumerate(network.actors.items()):
        ctl = network.control_specs[name]
        rows.append(FiringRow(
            name=name,
            index=index,
            control=None if ctl is None else ctl[1],
            inputs=tuple(PortBinding(p, fi)
                         for p, _, fi in network.in_port_specs[name]),
            outputs=tuple(PortBinding(p, fi)
                          for p, _, fi in network.out_port_specs[name]),
            is_dynamic=actor.is_dynamic,
            has_ready=actor.ready is not None,
        ))
    period = phase_unroll_period(
        [spec.n_write_phases for name, spec in network.fifos.items()
         if name not in network.register_fifos])
    return MegakernelLayout(
        fifo_names=fifo_names,
        fifo_specs=fifo_specs,
        firing_table=tuple(rows),
        transient_fifos=frozenset(network.register_fifos),
        unroll_period=period,
    )


# --------------------------------------------------------------------------- #
# Grid partitioning: actors -> cores (paper §3.3 actor-to-core mapping).
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class GridPartition:
    """Actor-to-core mapping of one lowered network (paper §3.3).

    ``assignment[i]`` is the core owning actor ``i`` (firing-table
    index); ``core_rows[c]`` are core ``c``'s firing-table indices in
    visit order — each core's occupancy-bounded firing loop iterates
    exactly that slice.  ``fifo_cores[f]`` is the core whose *private*
    scratch block holds channel ``f``'s ring (both endpoints on that
    core), or :data:`SHARED` for a partition-crossing channel: its ring
    lives in the shared block and its packed cursor row (monotonic
    rd / wr / occ counters) doubles as the cross-core semaphore the
    remote ``_can_fire`` polls — the device-resident analogue of
    ``heterogeneous_split``'s boundary feed/fetch actors.

    ``forwarded_fifos`` are the channels the kernel lowers to
    **loop-carried token windows** instead of scratch rings: the
    core-private subset of ``MegakernelLayout.transient_fifos`` (a
    crossing channel cannot be forwarded — a loop-carried value has no
    cross-core visibility, so it must stay a semaphore-guarded shared
    ring).  Forwarded channels keep their cursor rows (still part of the
    bit-identity contract) but contribute zero ring scratch; their
    buffer content follows the static specializer's dead-slot rule (see
    ``kernel.py``).

    Built by :func:`partition_layout`; the default assignment is a
    contiguous cut of the dynamic visit order with the endpoints of
    window-uncovered delay channels glued together
    (``Network.delay_partition_constraints``), minimizing crossing ring
    bytes within a load-balance slack (``objective="crossing"``) or the
    ``cost_flops`` bottleneck alone (``objective="flops"``).
    """

    n_cores: int
    assignment: Tuple[int, ...]
    core_rows: Tuple[Tuple[int, ...], ...]
    fifo_cores: Tuple[int, ...]
    forwarded_fifos: Tuple[int, ...] = ()
    objective: str = "crossing"

    @property
    def shared_fifos(self) -> Tuple[int, ...]:
        """Flat indices of partition-crossing channels (semaphore-guarded)."""
        return tuple(i for i, c in enumerate(self.fifo_cores) if c == SHARED)

    def private_fifos(self, core: int) -> Tuple[int, ...]:
        return tuple(i for i, c in enumerate(self.fifo_cores) if c == core)

    # -- cursor-block split (per-core private blocks + shared block) ---- #
    @property
    def cursor_rows(self) -> Tuple[Tuple[int, ...], ...]:
        """Channel indices per cursor block: ``n_cores`` private blocks
        (each core's own channels, forwarded included — forwarding
        reclaims the ring, never the cursors) followed by the shared
        block (the crossing channels' semaphore rows).  Every channel
        appears in exactly one block; the kernel loop-carries one packed
        ``(len(rows), 3)`` array per block, so a core's firing loop only
        touches its own block plus the shared one — the coherence surface
        a parallel grid mapping must fence is exactly the last block.
        """
        return tuple(self.private_fifos(core)
                     for core in range(self.n_cores)) + (self.shared_fifos,)

    @property
    def core_cursor_rows(self) -> Tuple[int, ...]:
        """Number of private cursor rows per core (the per-core split)."""
        return tuple(len(self.private_fifos(c)) for c in range(self.n_cores))

    # -- scratch accounting (per-core Table 1, device-side) ------------- #
    def private_ring_bytes(self, layout: "MegakernelLayout") -> Tuple[int, ...]:
        """Ring bytes held in each core's private scratch block
        (forwarded channels contribute nothing — they have no ring)."""
        fwd = set(self.forwarded_fifos)
        return tuple(
            sum(layout.fifo_specs[i].capacity_bytes
                for i in self.private_fifos(core) if i not in fwd)
            for core in range(self.n_cores))

    def shared_ring_bytes(self, layout: "MegakernelLayout") -> int:
        """Ring bytes of the shared (partition-crossing) block."""
        return sum(layout.fifo_specs[i].capacity_bytes
                   for i in self.shared_fifos)

    def reclaimed_ring_bytes(self, layout: "MegakernelLayout") -> int:
        """Ring bytes transient forwarding reclaims from scratch (the
        forwarded channels' Eq. 1 capacities)."""
        return sum(layout.fifo_specs[i].capacity_bytes
                   for i in self.forwarded_fifos)

    def scratch_bytes(self, layout: "MegakernelLayout") -> int:
        """Effective kernel scratch under this partition: buffered rings
        (private + shared) plus the full cursor block — i.e. the layout's
        no-forwarding footprint minus the reclaimed ring bytes."""
        return layout.scratch_bytes - self.reclaimed_ring_bytes(layout)

    def semaphore_bytes(self) -> int:
        """Bytes of shared cursor rows polled as cross-core semaphores."""
        return len(self.shared_fifos) * CURSOR_FIELDS * _CURSOR_ITEMSIZE


def _glued_units(network: Network) -> List[List[int]]:
    """Actor indices grouped into partition units, in first-member order.

    Union-find over :meth:`Network.delay_partition_constraints`: the two
    endpoints of a delay channel whose initial tokens do not cover a
    read window must land on one core, so they form one indivisible
    unit in the contiguous cut.
    """
    names = list(network.actors)
    idx = {n: i for i, n in enumerate(names)}
    parent = list(range(len(names)))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for _, src, dst in network.delay_partition_constraints():
        a, b = find(idx[src]), find(idx[dst])
        if a != b:
            parent[max(a, b)] = min(a, b)
    units: List[List[int]] = []
    unit_of_root: dict = {}
    for i in range(len(names)):
        r = find(i)
        if r not in unit_of_root:
            unit_of_root[r] = len(units)
            units.append([])
        units[unit_of_root[r]].append(i)
    return units


def _balanced_cut(weights: List[int], cores: int) -> Tuple[List[int], int]:
    """Contiguous cut of ``weights`` into ``cores`` groups minimizing the
    maximum group weight (classic linear-partition DP; deterministic —
    ties break toward earlier cuts).  Returns ``(group index per unit,
    optimal bottleneck weight)``.
    """
    n = len(weights)
    prefix = [0]
    for w in weights:
        prefix.append(prefix[-1] + w)

    def span(i: int, j: int) -> int:          # weight of units [i, j)
        return prefix[j] - prefix[i]

    INF = float("inf")
    # best[c][j]: minimal max-group-weight cutting units [0, j) into c groups.
    best = [[INF] * (n + 1) for _ in range(cores + 1)]
    cut = [[0] * (n + 1) for _ in range(cores + 1)]
    best[0][0] = 0
    for c in range(1, cores + 1):
        for j in range(c, n + 1):
            for i in range(c - 1, j):
                cand = max(best[c - 1][i], span(i, j))
                if cand < best[c][j]:
                    best[c][j] = cand
                    cut[c][j] = i
    groups = [0] * n
    j = n
    for c in range(cores, 0, -1):
        i = cut[c][j]
        for u in range(i, j):
            groups[u] = c - 1
        j = i
    return groups, int(best[cores][n])


def _crossing_cut(weights: List[int], spans: List[Tuple[int, int, int]],
                  cores: int, bottleneck_cap: int) -> List[int]:
    """Contiguous cut minimizing total crossing ring bytes subject to a
    ``cost_flops`` bottleneck cap.

    ``spans`` lists each channel as ``(umin, umax, capacity_bytes)`` over
    unit indices.  A channel crosses iff its endpoints land in different
    groups — for contiguous groups, iff some group boundary falls inside
    ``(umin, umax]``.  Counting it once, attributed to the group holding
    its left endpoint: define ``X(i, j)`` as the bytes of channels with
    ``i <= umin < j <= umax`` (left endpoint inside the group ``[i, j)``,
    right endpoint beyond its end) — summing ``X`` over the groups of any
    contiguous cut counts every crossing channel exactly once.  The DP
    then minimizes ``(total crossing bytes, bottleneck)``
    lexicographically over cuts whose every group weight stays within
    ``bottleneck_cap`` (the flops-only optimum times the slack, so the
    flops-optimal cut is always feasible and the DP cannot come up
    empty).  Deterministic: ties break toward earlier cuts.
    """
    n = len(weights)
    prefix = [0]
    for w in weights:
        prefix.append(prefix[-1] + w)

    def span_w(i: int, j: int) -> int:
        return prefix[j] - prefix[i]

    # cross[i][j] = X(i, j): channels leaving group [i, j) to the right.
    cross = [[0] * (n + 1) for _ in range(n + 1)]
    for i in range(n):
        for j in range(i + 1, n + 1):
            cross[i][j] = sum(b for a, z, b in spans if i <= a < j <= z)

    INF = (float("inf"), float("inf"))
    best = [[INF] * (n + 1) for _ in range(cores + 1)]
    cut = [[0] * (n + 1) for _ in range(cores + 1)]
    best[0][0] = (0, 0)
    for c in range(1, cores + 1):
        for j in range(c, n + 1):
            for i in range(c - 1, j):
                if best[c - 1][i] == INF or span_w(i, j) > bottleneck_cap:
                    continue
                cand = (best[c - 1][i][0] + cross[i][j],
                        max(best[c - 1][i][1], span_w(i, j)))
                if cand < best[c][j]:
                    best[c][j] = cand
                    cut[c][j] = i
    assert best[cores][n] != INF, "bottleneck_cap below the flops optimum"
    groups = [0] * n
    j = n
    for c in range(cores, 0, -1):
        i = cut[c][j]
        for u in range(i, j):
            groups[u] = c - 1
        j = i
    return groups


def default_assignment(network: Network, cores: int,
                       layout: Optional[MegakernelLayout] = None,
                       objective: str = "crossing",
                       profile: Optional[Mapping[str, Mapping[str, int]]]
                       = None) -> dict:
    """Default actor -> core map: a contiguous cut of the dynamic visit
    order (declaration order), with window-uncovered delay-channel
    endpoints glued into one unit.  Contiguity keeps the multi-core
    visit order equal to the single-core sweep's, so the interpret-mode
    tie-break (partition order) reproduces the single-core schedule
    exactly — for either objective.

    ``objective="flops"`` balances ``cost_flops`` alone (floor 1 per
    actor so zero-cost sources/sinks still count as schedulable work).
    ``objective="crossing"`` (default; needs ``layout`` for the Eq. 1
    ring bytes, else it degrades to the flops cut) picks, among cuts
    whose flops bottleneck stays within :data:`_CUT_BALANCE_SLACK` of
    the optimum, the one minimizing partition-crossing ring bytes — the
    shared-scratch / semaphore surface, and exactly the bytes transient
    forwarding would otherwise reclaim (a crossing transient channel
    falls back to a shared ring).
    ``objective="profile"`` is the crossing cut over *measured* weights:
    per-actor load (firings x flops) and per-channel occupancy-churn
    bytes from a traced run, passed as ``profile={"actors": {...},
    "channels": {...}}`` (``Profile.as_cut_weights()``).  Still a
    contiguous cut of the same glued units, so the Kahn bit-identity
    argument is unchanged — only the boundary placement moves.
    """
    if objective not in CUT_OBJECTIVES:
        raise ValueError(
            f"partition cut objective must be one of {CUT_OBJECTIVES}, "
            f"got {objective!r}")
    if objective == "profile" and profile is None:
        raise ValueError(
            "cut_objective='profile' needs measured weights: run once "
            "with ExecutionPlan(trace=True), then pass "
            "RunResult.trace.profile().as_cut_weights()")
    names = list(network.actors)
    units = _glued_units(network)
    if cores > len(units):
        raise ValueError(
            f"cores={cores} exceeds the {len(units)} partition units of "
            f"this network ({len(names)} actors after gluing delay-channel "
            "endpoints); pass fewer cores or an explicit assign= that "
            "leaves no core empty")
    if objective == "profile":
        actor_w = dict(profile.get("actors", {}))
        weights = [
            sum(max(1, int(actor_w.get(names[i], 1))) for i in u)
            for u in units
        ]
    else:
        weights = [
            sum(max(1, int(network.actors[names[i]].cost_flops)) for i in u)
            for u in units
        ]
    groups, bottleneck = _balanced_cut(weights, cores)
    if (objective == "profile" or
            (objective == "crossing" and layout is not None)) and cores > 1:
        unit_of = {}
        for ui, unit in enumerate(units):
            for i in unit:
                unit_of[i] = ui
        idx = {n: i for i, n in enumerate(names)}
        chan_w = (dict(profile.get("channels", {}))
                  if objective == "profile" else None)
        spans = []
        for fname in network.fifos:
            if objective == "crossing" and fname not in layout.fifo_names:
                continue
            e = network.edge_of(fname)
            a, b = unit_of[idx[e.src_actor]], unit_of[idx[e.dst_actor]]
            if a != b:
                bytes_w = (max(0, int(chan_w.get(fname, 0)))
                           if chan_w is not None
                           else network.fifos[fname].capacity_bytes)
                spans.append((min(a, b), max(a, b), bytes_w))
        cap = max(bottleneck, int(bottleneck * _CUT_BALANCE_SLACK))
        groups = _crossing_cut(weights, spans, cores, cap)
    out = {}
    for ui, unit in enumerate(units):
        for i in unit:
            out[names[i]] = groups[ui]
    return out


def partition_layout(network: Network, layout: MegakernelLayout,
                     cores: int = 1,
                     assign: Optional[Mapping[str, int]] = None,
                     objective: str = "crossing",
                     forward_transients: bool = True,
                     profile: Optional[Mapping[str, Mapping[str, int]]]
                     = None) -> GridPartition:
    """Partition the firing table across ``cores`` grid partitions.

    ``assign`` (actor name -> core) overrides the default cut; it must
    cover every actor and respect the delay-channel constraint
    (``Network.validate_partition``).  ``objective`` picks the default
    cut's criterion (see :func:`default_assignment`); under an explicit
    ``assign`` no heuristic runs and the partition records
    ``objective="assign"``.  ``profile`` carries the measured weights the
    ``"profile"`` objective cuts on (ignored otherwise).  Intra-partition
    channels are placed in the
    owning core's private scratch block; partition-crossing channels go
    :data:`SHARED` with their cursor rows acting as the polled
    semaphores.  With ``forward_transients`` (default) the core-private
    subset of ``layout.transient_fifos`` is marked forwarded: the kernel
    lowers those channels to loop-carried token windows with zero ring
    scratch (``GridPartition.forwarded_fifos``).
    """
    if cores < 1:
        raise ValueError(f"cores must be >= 1, got {cores}")
    if objective not in CUT_OBJECTIVES:
        raise ValueError(
            f"partition cut objective must be one of {CUT_OBJECTIVES}, "
            f"got {objective!r}")
    if assign is None:
        assign = default_assignment(network, cores, layout=layout,
                                    objective=objective, profile=profile)
    else:
        objective = "assign"    # explicit map: no cut heuristic ran
    network.validate_partition(assign, cores)
    names = list(network.actors)
    assignment = tuple(int(assign[n]) for n in names)
    core_rows = tuple(
        tuple(i for i, n in enumerate(names) if assignment[i] == core)
        for core in range(cores))
    fifo_cores = []
    for fname in layout.fifo_names:
        e = network.edge_of(fname)
        src = assignment[names.index(e.src_actor)]
        dst = assignment[names.index(e.dst_actor)]
        fifo_cores.append(src if src == dst else SHARED)
    forwarded = ()
    if forward_transients:
        forwarded = tuple(
            i for i, fname in enumerate(layout.fifo_names)
            if fname in layout.transient_fifos and fifo_cores[i] != SHARED)
        # Transient channels are delay-free by construction (FifoSpec
        # rejects matched_rates+delay; control channels carry no delay),
        # so the forwarded path never needs the Fig. 2 copy-back.  A
        # hard error (not an assert): forwarding a delayed channel would
        # silently corrupt bytes, the copy-back only exists on the ring
        # path.
        delayed = [layout.fifo_names[i] for i in forwarded
                   if layout.fifo_specs[i].delay]
        if delayed:
            raise ValueError(
                f"transient channels {delayed} carry delay tokens; "
                "register_fifos must never admit delayed channels "
                "(forwarding has no Fig. 2 copy-back)")
    return GridPartition(n_cores=cores, assignment=assignment,
                         core_rows=core_rows,
                         fifo_cores=tuple(fifo_cores),
                         forwarded_fifos=forwarded,
                         objective=objective)


def entry_staging_bytes(layout: "MegakernelLayout",
                        partition: Optional["GridPartition"] = None) -> int:
    """Bytes re-staged HBM -> kernel scratch on EVERY kernel entry: the
    effective ring + cursor footprint (forwarded transients excluded
    under ``partition``).  This is the per-chunk residency cost of
    driving the megakernel through ``Program.stream``'s chunked loop —
    persistent-feed mode pays it once instead of once per chunk."""
    if partition is not None:
        return partition.scratch_bytes(layout)
    return layout.scratch_bytes


def state_hbm_bytes(state: Any) -> int:
    """Total bytes of a state pytree as it sits in HBM (kernel in/out
    operands: ring buffers, cursors, actor states) — the 'HBM' column of
    the scratch-vs-HBM table in EXPERIMENTS.md §Megakernel."""
    total = 0
    for leaf in jax.tree.leaves(state):
        total += (int(np.prod(np.shape(leaf), dtype=np.int64))
                  * np.dtype(leaf.dtype).itemsize)
    return total
