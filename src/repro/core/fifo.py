"""FIFO communication channels — paper §3.2.

Implements the paper's exact channel-capacity law (Eq. 1):

    C_f = S_f * (3r + 1)   if f carries a delay (initial) token
    C_f = S_f * (2r)       otherwise

where ``r`` is the token rate of the channel and ``S_f`` the size of one
token.  The non-delay channel is a double buffer; the delay channel is the
paper's Fig. 2 triple buffer with an explicit copy-back (slot ``3r`` ->
slot ``0``) so that every read and write window stays **contiguous** — the
property the paper chose so accelerator kernels always see contiguous I/O
arrays.  On TPU that property matters even more: Pallas BlockSpec windows
and DMA transfers want contiguous slabs, so the scheme transfers verbatim.

Timing note (safe generalization of Fig. 2): the paper performs the
copy-back "after the third write reaches slot 3r".  If the writer is a full
capacity ahead of the reader, copying at that instant would clobber the
still-unread slot 0.  We therefore defer the copy to the *start of the next
wrapped write* (write phase 0), at which point the blocking condition
``occ + r <= 3r + 1`` guarantees the reader has consumed slot 0.  For every
interleaving legal under blocking semantics the observable FIFO behaviour
is identical to the paper's description (property-tested against a Python
queue oracle in ``tests/test_core_properties.py``).

State is purely functional: a :class:`FifoState` pytree is threaded through
the compiled executors (``lax.scan`` / ``lax.while_loop``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FifoState:
    """Functional state of one FIFO channel.

    Attributes:
      buf:   ``(capacity_tokens, *token_shape)`` backing array.
      rd:    read phase counter   (int32, monotonically increasing).
      wr:    write phase counter  (int32, monotonically increasing).
      occ:   occupancy in tokens  (int32).
    """

    buf: jax.Array
    rd: jax.Array
    wr: jax.Array
    occ: jax.Array


@dataclasses.dataclass(frozen=True)
class FifoSpec:
    """Static description of a FIFO channel (paper §2.2, §3.2).

    ``rate`` is the single token rate ``r`` associated with the channel;
    both the producing and the consuming port inherit it.  ``delay`` is the
    number of initial tokens (0 or 1 — the paper allows at most one).
    """

    name: str
    rate: int
    token_shape: Tuple[int, ...]
    dtype: Any = jnp.float32
    delay: int = 0
    # Control channels must have rate 1 (paper §2.2). Marked so the network
    # validator can enforce it.
    is_control: bool = False
    # Optional declared value domain ``(lo, hi)`` of every token element.
    # The health layer's guards (repro.core.health) flag an enabled window
    # carrying values outside [lo, hi] with the DOMAIN fault bit — the
    # integer-channel analogue of the NONFINITE guard (a slot-table row
    # full of garbage is as much a poisoned token as a NaN activation),
    # and Program.stream validates staged feed windows against it host-
    # side before anything runs.  None (default) disables the check.
    domain: Optional[Tuple[float, float]] = None
    # For channels whose tokens are stacks of record rows (axis 0 of the
    # token indexes the record): the column holding each record's id, so
    # fault reports and feed-validation errors can name the offending
    # record (e.g. the serving slot table's request-id column) instead of
    # just the channel.  Requires a >= 2-D token shape.
    row_id_col: Optional[int] = None
    # Declares that the producing and consuming ports are always enabled
    # together (their control functions derive the same 0/r decision, as in
    # DPD where one configuration value drives both ends of every branch
    # channel).  Under that invariant a delay-free channel is *transient* in
    # the static schedule — occupancy returns to 0 every iteration — and
    # ``compile_static(specialize=True)`` register-allocates it: the window
    # flows producer->consumer as a traced value inside the fused program
    # and the ring buffer is never touched.  Channels between two static
    # actors (or into a control port) are registerized automatically; this
    # flag extends that to dynamic ports whose enables are structurally
    # matched.  Declaring it on mismatched ports yields the same stale-slot
    # hazards the buffered masked path already has — just sooner.
    matched_rates: bool = False

    def __post_init__(self) -> None:
        if self.rate < 1:
            raise ValueError(f"fifo {self.name}: rate must be >= 1, got {self.rate}")
        if self.matched_rates and self.delay:
            raise ValueError(
                f"fifo {self.name}: matched_rates is a transient-channel "
                "declaration; a delay channel carries tokens across "
                "iterations and can never be register-allocated"
            )
        if self.delay not in (0, 1):
            raise ValueError(
                f"fifo {self.name}: the MoC allows 0 or 1 initial tokens, got {self.delay}"
            )
        if self.is_control and self.rate != 1:
            raise ValueError(
                f"fifo {self.name}: control channels must have token rate 1 "
                f"(paper §2.2), got {self.rate}"
            )
        if self.is_control and self.delay:
            raise ValueError(
                f"fifo {self.name}: control channels cannot carry delay tokens"
            )
        if self.domain is not None:
            lo, hi = self.domain
            if not (float(lo) <= float(hi)):
                raise ValueError(
                    f"fifo {self.name}: domain=({lo}, {hi}) is empty; "
                    "declare (lo, hi) with lo <= hi")
            object.__setattr__(self, "domain", (float(lo), float(hi)))
        if self.row_id_col is not None:
            if len(self.token_shape) < 2:
                raise ValueError(
                    f"fifo {self.name}: row_id_col names a column of "
                    "record-row tokens, so the token shape must be >= 2-D, "
                    f"got {self.token_shape}")
            if not (0 <= int(self.row_id_col) < self.token_shape[-1]):
                raise ValueError(
                    f"fifo {self.name}: row_id_col={self.row_id_col} is "
                    f"outside the token row width {self.token_shape[-1]}")

    # ------------------------------------------------------------------ #
    # Capacity law — paper Eq. 1.                                          #
    # ------------------------------------------------------------------ #
    @property
    def capacity_tokens(self) -> int:
        """Channel capacity in tokens: ``3r + 1`` with delay, ``2r`` without."""
        return 3 * self.rate + 1 if self.delay else 2 * self.rate

    @property
    def token_size_bytes(self) -> int:
        """S_f — size of one token in bytes."""
        return int(np.prod(self.token_shape, dtype=np.int64)) * jnp.dtype(self.dtype).itemsize

    @property
    def capacity_bytes(self) -> int:
        """C_f of Eq. 1, in bytes."""
        return self.capacity_tokens * self.token_size_bytes

    @property
    def n_write_phases(self) -> int:
        return 3 if self.delay else 2

    # ------------------------------------------------------------------ #
    # State construction.                                                  #
    # ------------------------------------------------------------------ #
    def init_state(self, initial_token: Optional[jax.Array] = None) -> FifoState:
        """Allocate the channel at application initialization.

        With ``delay=1`` the initial token (defaults to zeros) is placed in
        slot 0, exactly as in paper Fig. 2, and occupancy starts at 1.
        """
        buf = jnp.zeros((self.capacity_tokens,) + tuple(self.token_shape), self.dtype)
        if self.delay:
            if initial_token is not None:
                tok = jnp.asarray(initial_token, self.dtype)
                if tok.shape != tuple(self.token_shape):
                    raise ValueError(
                        f"fifo {self.name}: initial token shape {tok.shape} != "
                        f"token shape {self.token_shape}"
                    )
                buf = buf.at[0].set(tok)
        elif initial_token is not None:
            raise ValueError(f"fifo {self.name}: initial token on a delay-free channel")
        # Note: distinct zero buffers — donated executors reject aliased args.
        return FifoState(buf=buf, rd=jnp.int32(0), wr=jnp.int32(0),
                         occ=jnp.int32(self.delay))

    def abstract_state(self) -> FifoState:
        """ShapeDtypeStruct stand-in (for lowering without allocation)."""
        i32 = jax.ShapeDtypeStruct((), jnp.int32)
        return FifoState(
            buf=jax.ShapeDtypeStruct(
                (self.capacity_tokens,) + tuple(self.token_shape), jnp.dtype(self.dtype)
            ),
            rd=i32,
            wr=i32,
            occ=i32,
        )

    # ------------------------------------------------------------------ #
    # Cursor arithmetic.                                                   #
    # ------------------------------------------------------------------ #
    def _read_offset(self, rd_phase: jax.Array) -> jax.Array:
        """Slot index where the window of read phase ``rd`` begins.

        Non-delay double buffer: phases alternate 0, r.
        Delay triple buffer (Fig. 2): phases cycle 0, r, 2r.
        """
        ph = rd_phase % self.n_write_phases
        return ph * self.rate

    def _write_offset(self, wr_phase: jax.Array) -> jax.Array:
        """Slot index where the window of write phase ``wr`` begins.

        Delay channels are offset by +1 because slot 0 belongs to the
        (copied-back) delay token — paper Fig. 2: first write occupies
        slots 1..r.
        """
        ph = wr_phase % self.n_write_phases
        return ph * self.rate + (1 if self.delay else 0)

    # ------------------------------------------------------------------ #
    # Trace-time cursor specialization (EXPERIMENTS.md §Executor perf).    #
    #                                                                      #
    # In the single-appearance static schedule every actor fires exactly   #
    # once per iteration, so a port that consumes/produces unconditionally #
    # advances its cursor by exactly 1 per iteration: starting from        #
    # ``init_state`` (rd = wr = 0), the cursor at iteration ``i`` *is*     #
    # ``i`` and the slot offset is the compile-time constant               #
    # ``(i % n_write_phases) * rate``.  ``compile_static`` unrolls the     #
    # phase cycle (LCM of n_write_phases over the network, <= 6) and calls #
    # these ``*_static`` variants with a Python-int phase — every          #
    # dynamic_slice / dynamic_update_slice of the cursor-driven API        #
    # becomes a static slice XLA can fold, fuse and update in place.       #
    # ------------------------------------------------------------------ #
    def read_offset_static(self, phase: int) -> int:
        """Compile-time slot offset of read phase ``phase`` (a Python int)."""
        return (phase % self.n_write_phases) * self.rate

    def write_offset_static(self, phase: int) -> int:
        """Compile-time slot offset of write phase ``phase`` (a Python int)."""
        return (phase % self.n_write_phases) * self.rate + (1 if self.delay else 0)

    def read_static(self, st: FifoState, phase: int) -> Tuple[jax.Array, FifoState]:
        """``read`` with the cursor specialized to trace-time ``phase``.

        Caller guarantees ``st.rd % n_write_phases == phase % n_write_phases``
        (true from ``init_state`` when the reader consumes every iteration).
        Counters still advance so the resulting state is bit-identical to
        the dynamic-cursor path.
        """
        off = self.read_offset_static(phase)
        window = jax.lax.slice_in_dim(st.buf, off, off + self.rate, axis=0)
        return window, FifoState(buf=st.buf, rd=st.rd + 1, wr=st.wr, occ=st.occ - self.rate)

    def peek_static(self, st: FifoState, phase: int) -> jax.Array:
        """``peek`` with a trace-time phase (static single-token slice)."""
        off = self.read_offset_static(phase)
        return jax.lax.slice_in_dim(st.buf, off, off + 1, axis=0)[0]

    def write_static(self, st: FifoState, tokens: jax.Array, phase: int) -> FifoState:
        """``write`` with the cursor specialized to trace-time ``phase``.

        The Fig. 2 delay-channel copy-back happens iff ``phase == 2`` —
        decided at trace time, so the non-copy-back phases carry no
        ``lax.cond`` at all.
        """
        tokens = jnp.asarray(tokens, self.dtype)
        off = self.write_offset_static(phase)
        # dynamic_update_slice with a *constant* start index — not .at[].set,
        # whose general-gather/scatter lowering is far slower on CPU.
        buf = jax.lax.dynamic_update_slice_in_dim(st.buf, tokens, off, axis=0)
        if self.delay and phase % self.n_write_phases == 2:
            copy = jax.lax.slice_in_dim(buf, 3 * self.rate, 3 * self.rate + 1, axis=0)
            buf = jax.lax.dynamic_update_slice_in_dim(buf, copy, 0, axis=0)
        return FifoState(buf=buf, rd=st.rd, wr=st.wr + 1, occ=st.occ + self.rate)

    # ------------------------------------------------------------------ #
    # Blocking predicates (used by the dynamic scheduler).                 #
    # ------------------------------------------------------------------ #
    @property
    def writable_occupancy_bound(self) -> int:
        """Maximum occupancy after a write.

        Non-delay double buffer: the full ``2r`` capacity.
        Delay triple buffer: ``2r + 1`` — *less* than the physical ``3r+1``
        of Eq. 1.  The Fig. 2 phase pattern reuses a slot only when the
        write cycle returns to it, so the writer may run at most one full
        window ahead of the reader; but the unread span then straddles
        *three* phase windows, which is exactly why Eq. 1 allocates 3r+1
        physical slots for 2r+1 logical tokens (property-tested against a
        queue oracle in tests/test_core_fifo.py).
        """
        return 2 * self.rate + 1 if self.delay else 2 * self.rate

    def can_read(self, st: FifoState) -> jax.Array:
        return st.occ >= self.rate

    def can_write(self, st: FifoState) -> jax.Array:
        return st.occ + self.rate <= self.writable_occupancy_bound

    def can_peek(self, st: FifoState) -> jax.Array:
        return st.occ >= 1

    # ------------------------------------------------------------------ #
    # Functional read / write / peek.                                      #
    # ------------------------------------------------------------------ #
    def write(self, st: FifoState, tokens: jax.Array) -> FifoState:
        """Append one window of ``r`` tokens. Caller guarantees ``can_write``.

        ``tokens`` has shape ``(r, *token_shape)``.  For delay channels the
        Fig. 2 copy-back (slot 3r -> slot 0) runs **eagerly right after the
        phase-2 write reaches the buffer end** — the paper's own timing
        ("the third write ... followed by an explicit data copy").  It is
        safe because the phase blocking bound (writer at most one window
        ahead, see ``writable_occupancy_bound``) guarantees slot 0 was
        consumed by the corresponding phase-0 read; and it must not be
        deferred, because the *next* phase-0 read sources slot 0.
        Both directions are pinned by the queue-oracle property test.
        """
        tokens = jnp.asarray(tokens, self.dtype)
        off = self._write_offset(st.wr)
        buf = jax.lax.dynamic_update_slice_in_dim(st.buf, tokens, off, axis=0)
        if self.delay:
            is_phase2 = (st.wr % self.n_write_phases) == 2

            def do_copy(b):
                return b.at[0].set(b[3 * self.rate])

            buf = jax.lax.cond(is_phase2, do_copy, lambda b: b, buf)
        return FifoState(buf=buf, rd=st.rd, wr=st.wr + 1, occ=st.occ + self.rate)

    def read(self, st: FifoState) -> Tuple[jax.Array, FifoState]:
        """Consume one window of ``r`` tokens. Caller guarantees ``can_read``."""
        off = self._read_offset(st.rd)
        window = jax.lax.dynamic_slice_in_dim(st.buf, off, self.rate, axis=0)
        return window, FifoState(buf=st.buf, rd=st.rd + 1, wr=st.wr, occ=st.occ - self.rate)

    def peek(self, st: FifoState) -> jax.Array:
        """Return the *next single token* without consuming it.

        Used by the scheduler to evaluate a dynamic actor's ``control``
        function before committing to a firing (our shared-memory analogue
        of the paper's blocking control-port read).
        """
        off = self._read_offset(st.rd)
        return jax.lax.dynamic_slice_in_dim(st.buf, off, 1, axis=0)[0]

    def read_masked(self, st: FifoState, enabled: jax.Array) -> Tuple[jax.Array, FifoState]:
        """Rate-0/r read (paper §2.2 dynamic ports).

        Always returns a static-shaped ``(r, *token_shape)`` window (XLA
        needs static shapes) but only advances the cursor when ``enabled``.
        When disabled the window content is unspecified-by-the-MoC; we
        return the current slots (callers gate on ``enabled``).
        """
        off = self._read_offset(st.rd)
        window = jax.lax.dynamic_slice_in_dim(st.buf, off, self.rate, axis=0)
        e = enabled.astype(jnp.int32)
        new = FifoState(buf=st.buf, rd=st.rd + e, wr=st.wr, occ=st.occ - e * self.rate)
        return window, new

    def write_masked(self, st: FifoState, tokens: jax.Array, enabled: jax.Array) -> FifoState:
        """Rate-0/r write: commit the window only when ``enabled``.

        All channels avoid ``lax.cond`` on the buffer: a cond whose
        identity arm returns the buffer forces XLA to materialize a copy of
        the *whole* channel every firing (measured: FIFO-copy-bound DPD,
        EXPERIMENTS.md §Executor perf).  Instead the window slot is
        rewritten unconditionally with either the new tokens or its current
        content — an in-place dynamic-update-slice touching only r tokens.
        Delay channels additionally fold the Fig. 2 copy-back (slot 3r ->
        slot 0) into a predicated *single-token* rewrite of slot 0, instead
        of the full-buffer copy the old cond identity arm materialized.
        Pinned against the queue oracle in tests/test_core_fifo.py.
        """
        e = enabled.astype(jnp.int32)
        off = self._write_offset(st.wr)
        cur = jax.lax.dynamic_slice_in_dim(st.buf, off, self.rate, axis=0)
        eff = jnp.where(enabled, jnp.asarray(tokens, self.dtype), cur)
        buf = jax.lax.dynamic_update_slice_in_dim(st.buf, eff, off, axis=0)
        if self.delay:
            # Copy-back fires iff this is an *enabled* phase-2 write.
            do_copy = jnp.logical_and(enabled,
                                      (st.wr % self.n_write_phases) == 2)
            slot0 = jnp.where(do_copy, buf[3 * self.rate], buf[0])
            buf = buf.at[0].set(slot0)
        return FifoState(buf=buf, rd=st.rd, wr=st.wr + e,
                         occ=st.occ + e * self.rate)

    # ------------------------------------------------------------------ #
    # Guarded variants (repro.core.health).  Same channel operation, plus  #
    # the packed fault-bit word of the PRE-op state — guards observe, they #
    # never change what the operation does, so a guarded executor's state  #
    # stays bit-identical to the unguarded one.                            #
    # ------------------------------------------------------------------ #
    def read_guarded(self, st: FifoState) -> Tuple[jax.Array, FifoState, jax.Array]:
        """``read`` returning ``(window, new_state, fault_bits)``."""
        from repro.core.health import read_guard_bits
        window, new = self.read(st)
        bits = read_guard_bits(self, st.rd, st.wr, st.occ, jnp.bool_(True),
                               window)
        return window, new, bits

    def read_masked_guarded(self, st: FifoState, enabled: jax.Array
                            ) -> Tuple[jax.Array, FifoState, jax.Array]:
        """``read_masked`` returning ``(window, new_state, fault_bits)``."""
        from repro.core.health import read_guard_bits
        window, new = self.read_masked(st, enabled)
        bits = read_guard_bits(self, st.rd, st.wr, st.occ, enabled, window)
        return window, new, bits

    def write_masked_guarded(self, st: FifoState, tokens: jax.Array,
                             enabled: jax.Array
                             ) -> Tuple[FifoState, jax.Array, jax.Array]:
        """``write_masked`` returning ``(new_state, fault_bits, occ_after)``.

        ``occ_after`` is the **true** post-write occupancy recomputed from
        the monotonic cursors (not the possibly-corrupted ``occ`` counter)
        — the high-water quantity the health layer tracks per channel.
        """
        from repro.core.health import true_occupancy, write_guard_bits
        new = self.write_masked(st, tokens, enabled)
        bits = write_guard_bits(self, st.rd, st.wr, st.occ, enabled, tokens)
        e = enabled.astype(jnp.int32)
        occ_after = true_occupancy(self, st.rd, st.wr) + e * self.rate
        return new, bits, occ_after


def total_buffer_bytes(specs) -> int:
    """Sum of Eq. 1 capacities — reproduces the accounting of paper Table 1."""
    return sum(s.capacity_bytes for s in specs)
