"""Actor networks ℵ = (A, F) — paper §2.2.

A network is a set of actors interconnected by FIFO channels.  Each channel
connects exactly one output port to exactly one input port (paper §3.2).
Ports inherit the token rate of the channel they connect to.

The builder validates the MoC's structural rules at construction time:
  * single writer / single reader per channel;
  * control channels have rate 1 and no delay token;
  * every declared port is connected exactly once;
  * dynamic actors have exactly one control port fed by a channel.
"""
from __future__ import annotations

import dataclasses
import functools
import types
from typing import TYPE_CHECKING, Any, Dict, List, Mapping, Optional, Tuple

import jax

from repro.core.actor import ActorSpec
from repro.core.fifo import FifoSpec, FifoState, total_buffer_bytes

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (program -> network)
    from repro.core.program import ExecutionPlan, Program


@functools.lru_cache(maxsize=None)
def name_index_map(names: Tuple[str, ...]) -> Mapping[str, int]:
    """name -> position map for a static name tuple, computed once.

    The accessor hot path used to be ``tuple.index`` — an O(n) scan per
    lookup.  States sharing a name tuple (every state of one network) share
    one cached map; the tuple lives in static pytree metadata, so it is
    hashable and stable across jit retraces.  The cached map is returned
    read-only: every caller shares one object, so a mutation would corrupt
    lookups for all states of the network.
    """
    return types.MappingProxyType({n: i for i, n in enumerate(names)})


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class NetworkState:
    """Flat functional state of a whole network (one pytree, built once).

    FIFO and actor states are packed as *tuples* in network declaration
    order — the executors index them with build-time integer tables
    (``Network.in_port_specs`` &c.) instead of rebuilding name-keyed dicts
    on every firing, and the fixed treedef makes the state cheap to flatten
    per jitted dispatch and safe to donate (``donate_argnums``).

    ``fifo_names`` / ``actor_names`` are static pytree metadata; the
    mapping-style ``state["fifos"]`` / ``state["actors"]`` accessors keep
    the original dict-of-dicts read API working for callers (benchmarks,
    examples, ``collect_sink``).
    """

    fifos: Tuple[FifoState, ...]
    actors: Tuple[Any, ...]
    fifo_names: Tuple[str, ...] = dataclasses.field(metadata=dict(static=True))
    actor_names: Tuple[str, ...] = dataclasses.field(metadata=dict(static=True))

    # -- read accessors ------------------------------------------------- #
    def __getitem__(self, key: str) -> Dict[str, Any]:
        if key == "fifos":
            return dict(zip(self.fifo_names, self.fifos))
        if key == "actors":
            return dict(zip(self.actor_names, self.actors))
        raise KeyError(key)

    def fifo(self, name: str) -> FifoState:
        return self.fifos[name_index_map(self.fifo_names)[name]]

    def actor(self, name: str) -> Any:
        return self.actors[name_index_map(self.actor_names)[name]]

    # -- functional update helpers -------------------------------------- #
    def replace_actor(self, index: int, value: Any) -> "NetworkState":
        actors = self.actors[:index] + (value,) + self.actors[index + 1:]
        return dataclasses.replace(self, actors=actors)


@dataclasses.dataclass(frozen=True)
class Edge:
    """One channel binding: (src actor, src port) --fifo--> (dst actor, dst port)."""

    fifo: str
    src_actor: str
    src_port: str
    dst_actor: str
    dst_port: str


class Network:
    """Validated actor network (immutable after construction)."""

    def __init__(self, actors: List[ActorSpec], fifos: List[FifoSpec], edges: List[Edge],
                 initial_tokens: Optional[Mapping[str, Any]] = None):
        self.actors: Dict[str, ActorSpec] = {a.name: a for a in actors}
        self.fifos: Dict[str, FifoSpec] = {f.name: f for f in fifos}
        self.edges: Tuple[Edge, ...] = tuple(edges)
        self.initial_tokens: Dict[str, Any] = dict(initial_tokens or {})
        if len(self.actors) != len(actors):
            raise ValueError("duplicate actor names")
        if len(self.fifos) != len(fifos):
            raise ValueError("duplicate fifo names")
        self._edge_by_fifo: Dict[str, Edge] = {}
        for e in self.edges:
            if e.fifo in self._edge_by_fifo:
                raise ValueError(f"fifo {e.fifo} bound to more than one edge "
                                 f"(channels connect exactly one output to one input)")
            self._edge_by_fifo[e.fifo] = e
        self._validate()
        # Port -> fifo lookup tables used by the executors.
        self.in_fifo: Dict[Tuple[str, str], str] = {
            (e.dst_actor, e.dst_port): e.fifo for e in self.edges
        }
        self.out_fifo: Dict[Tuple[str, str], str] = {
            (e.src_actor, e.src_port): e.fifo for e in self.edges
        }
        # Flat-state index maps + per-actor port->spec tables, precomputed
        # once here so the traced executors never re-resolve name->spec
        # dict chains per firing / per sweep trace (hot-path hoisting).
        self.fifo_index: Dict[str, int] = {n: i for i, n in enumerate(self.fifos)}
        self.actor_index: Dict[str, int] = {n: i for i, n in enumerate(self.actors)}
        self.in_port_specs: Dict[str, Tuple[Tuple[str, FifoSpec, int], ...]] = {}
        self.out_port_specs: Dict[str, Tuple[Tuple[str, FifoSpec, int], ...]] = {}
        self.control_specs: Dict[str, Optional[Tuple[FifoSpec, int]]] = {}
        for name, a in self.actors.items():
            self.in_port_specs[name] = tuple(
                (p, self.fifos[self.in_fifo[(name, p)]],
                 self.fifo_index[self.in_fifo[(name, p)]])
                for p in a.in_ports)
            self.out_port_specs[name] = tuple(
                (p, self.fifos[self.out_fifo[(name, p)]],
                 self.fifo_index[self.out_fifo[(name, p)]])
                for p in a.out_ports)
            if a.control_port is not None:
                cf = self.in_fifo[(name, a.control_port)]
                self.control_specs[name] = (self.fifos[cf], self.fifo_index[cf])
            else:
                self.control_specs[name] = None
        # Register-allocatable (transient) channels for the specialized
        # static executor: delay-free channels whose two ports are provably
        # enabled together.  In a feasible single-appearance schedule such
        # a channel's occupancy returns to 0 inside every iteration, so the
        # fused program can forward the window producer->consumer as a
        # traced value and never touch the ring buffer.  Scope (measured,
        # EXPERIMENTS.md §Executor perf):
        #   * masked bulk channels declared via FifoSpec.matched_rates —
        #     forwarding erases the read-modify-write their masked ring
        #     writes otherwise pay;
        #   * control channels with a static producer — scalar tokens,
        #     trivially matched (both ports unconditional).
        # Bulk channels between two *static* actors are deliberately left
        # buffered: their static-offset ring write is a single contiguous
        # dynamic-update-slice that doubles as the materialization point
        # between actor bodies, whereas forwarding them lets XLA fuse
        # producer stencils into every consumer tap (25-tap gauss inside
        # each median tap: 10x+ slower on the CPU backend).
        reg = set()
        for e in self.edges:
            f = self.fifos[e.fifo]
            if f.delay:
                continue
            src_static = not self.actors[e.src_actor].is_dynamic
            if f.matched_rates or (f.is_control and src_static):
                reg.add(e.fifo)
        self.register_fifos: frozenset = frozenset(reg)

    # ------------------------------------------------------------------ #
    def _validate(self) -> None:
        for e in self.edges:
            if e.fifo not in self.fifos:
                raise ValueError(f"edge references unknown fifo {e.fifo}")
            if e.src_actor not in self.actors:
                raise ValueError(f"edge references unknown actor {e.src_actor}")
            if e.dst_actor not in self.actors:
                raise ValueError(f"edge references unknown actor {e.dst_actor}")
            src = self.actors[e.src_actor]
            dst = self.actors[e.dst_actor]
            if e.src_port not in src.out_ports:
                raise ValueError(f"{e.src_actor} has no output port {e.src_port}")
            if e.dst_port not in dst.all_in_ports():
                raise ValueError(f"{e.dst_actor} has no input port {e.dst_port}")
            if e.dst_port == dst.control_port and not self.fifos[e.fifo].is_control:
                raise ValueError(
                    f"fifo {e.fifo} feeds control port {e.dst_actor}.{e.dst_port} "
                    f"but is not marked is_control (rate-1 rule, paper §2.2)"
                )
        # Exactly-once connectivity.
        seen_src, seen_dst = set(), set()
        for e in self.edges:
            k_src, k_dst = (e.src_actor, e.src_port), (e.dst_actor, e.dst_port)
            if k_src in seen_src:
                raise ValueError(f"output port {k_src} connected twice")
            if k_dst in seen_dst:
                raise ValueError(f"input port {k_dst} connected twice")
            seen_src.add(k_src)
            seen_dst.add(k_dst)
        for a in self.actors.values():
            for p in a.all_in_ports():
                if (a.name, p) not in seen_dst:
                    raise ValueError(f"input port {a.name}.{p} not connected")
            for p in a.out_ports:
                if (a.name, p) not in seen_src:
                    raise ValueError(f"output port {a.name}.{p} not connected")
        for f in self.fifos.values():
            if f.name not in self._edge_by_fifo:
                raise ValueError(f"fifo {f.name} not bound to any edge")
        for name, tok in self.initial_tokens.items():
            if name not in self.fifos:
                raise ValueError(f"initial token for unknown fifo {name}")
            if not self.fifos[name].delay:
                raise ValueError(f"initial token for delay-free fifo {name}")

    # ------------------------------------------------------------------ #
    def edge_of(self, fifo_name: str) -> Edge:
        return self._edge_by_fifo[fifo_name]

    def fifo_for_in_port(self, actor: str, port: str) -> FifoSpec:
        return self.fifos[self.in_fifo[(actor, port)]]

    def fifo_for_out_port(self, actor: str, port: str) -> FifoSpec:
        return self.fifos[self.out_fifo[(actor, port)]]

    def sources(self) -> List[str]:
        return [a.name for a in self.actors.values() if a.is_source]

    def sinks(self) -> List[str]:
        return [a.name for a in self.actors.values() if a.is_sink]

    def buffer_bytes(self) -> int:
        """Total communication-buffer memory — paper Table 1 accounting."""
        return total_buffer_bytes(self.fifos.values())

    # ------------------------------------------------------------------ #
    # Compilation entrypoint (repro.core.program).                         #
    # ------------------------------------------------------------------ #
    def compile(self, plan: Optional["ExecutionPlan"] = None,
                **overrides: Any) -> "Program":
        """Compile this network under an :class:`ExecutionPlan`.

        The single entrypoint subsuming the legacy ``compile_static`` /
        ``compile_dynamic`` / ``run_interpreted`` trio: the execution
        strategy (mode, specialization, multi-firing, donation,
        heterogeneous placement) is data in the plan, not a choice of
        function.  Keyword ``overrides`` are applied on top of ``plan``
        (or of a default plan when none is given)::

            prog = net.compile(mode="static", n_iterations=8)
            result = prog.run()            # RunResult(state, ...)

        Returns a :class:`repro.core.program.Program`.
        """
        from repro.core.program import ExecutionPlan, Program
        if plan is None:
            plan = ExecutionPlan(**overrides)
        elif overrides:
            plan = dataclasses.replace(plan, **overrides)
        return Program(self, plan)

    # ------------------------------------------------------------------ #
    # Graphviz export (debugging builder-constructed graphs).              #
    # ------------------------------------------------------------------ #
    def to_dot(self, partition: Optional[Any] = None) -> str:
        """Render the network as a Graphviz ``digraph``.

        Actors are nodes (dynamic actors double-bordered, sources/sinks
        tinted); every channel is an edge labeled with its name, rate,
        Eq. 1 capacity and delay; control channels are dashed.  Paste the
        output into any dot viewer::

            print(net.to_dot())        # | dot -Tsvg > net.svg

        With a ``partition`` (a megakernel ``GridPartition``, e.g. from
        ``Program``'s plan or ``partition_layout``) each core's actors
        render as one ``cluster`` subgraph, partition-crossing channels
        are highlighted red with a ``[shared]`` marker (their rings +
        cursor semaphores are the cross-core coherence surface) and
        forwarded transients carry a ``[fwd]`` marker — a cut regression
        is visible at a glance.
        """
        def q(s: str) -> str:
            return '"' + s.replace('"', '\\"') + '"'

        names = list(self.actors)
        lines = [
            "digraph network {",
            "  rankdir=LR;",
            '  node [shape=box, style=rounded, fontname="Helvetica"];',
        ]

        def node_lines(subset, indent="  "):
            out = []
            for name in subset:
                a = self.actors[name]
                attrs = []
                if a.is_dynamic:
                    attrs.append("peripheries=2")
                    label = f"{name}\\n(dynamic, ctrl={a.control_port})"
                else:
                    label = name
                if a.is_source or a.is_sink:
                    attrs.append('style="rounded,filled"')
                    attrs.append('fillcolor="lightgrey"')
                attrs.insert(0, f"label={q(label)}")
                out.append(f"{indent}{q(name)} [{', '.join(attrs)}];")
            return out

        if partition is None:
            lines += node_lines(names)
        else:
            if (len(partition.assignment) != len(names)
                    or len(partition.fifo_cores) != len(self.fifos)):
                raise ValueError(
                    f"to_dot: partition covers {len(partition.assignment)} "
                    f"actors / {len(partition.fifo_cores)} channels but the "
                    f"network has {len(names)} / {len(self.fifos)}; pass "
                    "the GridPartition built from this network")
            for core, rows in enumerate(partition.core_rows):
                lines.append(f"  subgraph cluster_core{core} {{")
                lines.append(f'    label="core {core}"; style=dashed;')
                lines += node_lines([names[i] for i in rows], indent="    ")
                lines.append("  }")
        fifo_pos = {n: i for i, n in enumerate(self.fifos)}
        forwarded = (set(partition.forwarded_fifos)
                     if partition is not None else set())
        for e in self.edges:
            f = self.fifos[e.fifo]
            label = (f"{f.name}\\n{e.src_port}->{e.dst_port} "
                     f"r={f.rate} cap={f.capacity_tokens}")
            if f.delay:
                label += f" delay={f.delay}"
            attrs = []
            if f.is_control:
                attrs.append("style=dashed")
            if partition is not None:
                fi = fifo_pos[e.fifo]
                if partition.fifo_cores[fi] < 0:      # SHARED (crossing)
                    label += " [shared]"
                    attrs += ["color=red", "penwidth=2.0"]
                elif fi in forwarded:
                    label += " [fwd]"
            attrs.insert(0, f"label={q(label)}")
            lines.append(f"  {q(e.src_actor)} -> {q(e.dst_actor)} "
                         f"[{', '.join(attrs)}];")
        lines.append("}")
        return "\n".join(lines)

    # ------------------------------------------------------------------ #
    # State construction.                                                  #
    # ------------------------------------------------------------------ #
    def init_state(self) -> NetworkState:
        fifo_states = tuple(spec.init_state(self.initial_tokens.get(name))
                            for name, spec in self.fifos.items())
        actor_states = tuple(a.init_state() for a in self.actors.values())
        return NetworkState(fifos=fifo_states, actors=actor_states,
                            fifo_names=tuple(self.fifos),
                            actor_names=tuple(self.actors))

    def state_from_dict(self, state: Mapping[str, Any]) -> NetworkState:
        """Adapt a legacy ``{"fifos": {...}, "actors": {...}}`` dict state."""
        if isinstance(state, NetworkState):
            return state
        return NetworkState(
            fifos=tuple(state["fifos"][n] for n in self.fifos),
            actors=tuple(state["actors"][n] for n in self.actors),
            fifo_names=tuple(self.fifos), actor_names=tuple(self.actors))

    # ------------------------------------------------------------------ #
    # Graph utilities for the scheduler.                                   #
    # ------------------------------------------------------------------ #
    def precedence_edges(self, ignore_delay: bool = True) -> List[Tuple[str, str]]:
        """(producer, consumer) pairs for one-iteration scheduling.

        A delay token breaks producer->consumer precedence only when the
        initial tokens cover a whole read window, i.e. ``delay >= rate``.
        With the MoC's single delay token and r > 1, the first read still
        needs r-1 *fresh* tokens (paper Fig. 2: read 1 consumes slots
        0..r-1 = D plus write 1's prefix), so the producer keeps firing
        first and the delay merely shifts the data by one token.
        """
        out = []
        for e in self.edges:
            f = self.fifos[e.fifo]
            if ignore_delay and f.delay >= f.rate:
                continue
            out.append((e.src_actor, e.dst_actor))
        return out

    def topological_order(self) -> List[str]:
        """Topo sort with delay edges broken; raises on deadlock cycles.

        In this MoC every channel has the same rate at both ends, so the SDF
        repetition vector is all-ones and one *iteration* = one firing of
        every actor.  A cycle with no delay token can never fire — the
        classic dataflow deadlock — which we diagnose here at build time.
        """
        names = list(self.actors)
        idx = {n: i for i, n in enumerate(names)}
        n = len(names)
        adj = [[] for _ in range(n)]
        indeg = [0] * n
        for u, v in self.precedence_edges(ignore_delay=True):
            adj[idx[u]].append(idx[v])
            indeg[idx[v]] += 1
        order, stack = [], [i for i in range(n) if indeg[i] == 0]
        while stack:
            u = stack.pop()
            order.append(u)
            for v in adj[u]:
                indeg[v] -= 1
                if indeg[v] == 0:
                    stack.append(v)
        if len(order) != n:
            stuck = [names[i] for i in range(n) if indeg[i] > 0]
            raise ValueError(
                "network deadlock: cycle without an initial (delay) token "
                f"through actors {stuck} — paper §2.2 requires a delay token "
                "on feedback loops (IIR example)"
            )
        return [names[i] for i in order]

    def check_schedule_feasible(self) -> None:
        """Simulate one iteration of the single-appearance schedule with
        occupancy counters and verify Eq. 1 capacities are never exceeded
        and no read underflows (trace-time analogue of blocking semantics).
        """
        occ = {name: spec.delay for name, spec in self.fifos.items()}
        for actor in self.topological_order():
            a = self.actors[actor]
            for p in a.all_in_ports():
                f = self.fifo_for_in_port(actor, p)
                need = 1 if p == a.control_port else f.rate
                if occ[f.name] < need:
                    raise ValueError(
                        f"schedule infeasible: {actor}.{p} reads {need} from "
                        f"{f.name} holding {occ[f.name]}"
                    )
                occ[f.name] -= need
            for p in a.out_ports:
                f = self.fifo_for_out_port(actor, p)
                if occ[f.name] + f.rate > f.writable_occupancy_bound:
                    raise ValueError(
                        f"schedule infeasible: {actor}.{p} writes {f.rate} to "
                        f"{f.name} at {occ[f.name]}/{f.writable_occupancy_bound} "
                        f"— blocking bound violated (Eq. 1 phase pattern)"
                    )
                occ[f.name] += f.rate
        for name, spec in self.fifos.items():
            if occ[name] != spec.delay:
                raise ValueError(
                    f"unbalanced iteration: fifo {name} ends at occupancy "
                    f"{occ[name]} != initial {spec.delay}; single-appearance "
                    "schedule would grow without bound"
                )


    # ------------------------------------------------------------------ #
    # Grid partitioning (megakernel multi-core sweeps, paper §3.3).        #
    # ------------------------------------------------------------------ #
    def delay_partition_constraints(self) -> List[Tuple[str, str, str]]:
        """Delay channels whose endpoints must share a grid partition.

        Returns ``(fifo, src_actor, dst_actor)`` for every delay channel
        whose initial tokens do NOT cover a whole read window
        (``delay < rate``).  Such a channel's Fig. 2 copy-back (the
        writer's slot-``3r`` -> slot-``0`` rewrite) lands while the
        reader may legally hold a window overlapping slot 0 — on one
        core the sequential sweep orders the two accesses, but across
        cores the monotonic cursor "semaphores" give the remote reader
        no way to tell a copied-back slot 0 from a stale one mid-cycle.
        With ``delay >= rate`` the initial tokens keep the reader a full
        window behind the copy-back point and the blocking bound
        (``occ + r <= 2r + 1``) covers the crossing.
        """
        out = []
        for e in self.edges:
            f = self.fifos[e.fifo]
            if f.delay and f.delay < f.rate:
                out.append((e.fifo, e.src_actor, e.dst_actor))
        return out

    def validate_partition(self, assignment: Mapping[str, int],
                           cores: int, unit: str = "core") -> None:
        """Check an actor -> core map against the grid-partition rules.

        The map must cover every actor exactly (the megakernel firing
        table is partitioned, not filtered), name only known actors, use
        cores in ``[0, cores)``, and keep both endpoints of every
        delay channel with ``delay < rate`` on one core (see
        :meth:`delay_partition_constraints`).  Raises ``ValueError``
        with the offending actors/channels otherwise.

        ``unit`` names the partition axis in errors: ``"core"`` for the
        megakernel grid, ``"device"`` for multi-device sharded plans
        (``ExecutionPlan(devices=k)``) — the rules are identical, only
        the synchronization primitive differs (polled cursor semaphores
        vs sweep-barrier collectives), and the delay-channel constraint
        covers both for the same Fig. 2 copy-back reason.
        """
        unknown = set(assignment) - set(self.actors)
        if unknown:
            raise ValueError(
                f"partition assignment names unknown actors "
                f"{sorted(unknown)}; known: {sorted(self.actors)}")
        missing = set(self.actors) - set(assignment)
        if missing:
            raise ValueError(
                f"partition assignment must map every actor to a {unit} "
                f"(the firing table is partitioned, not filtered); "
                f"missing {sorted(missing)}")
        bad = {n: c for n, c in assignment.items()
               if not isinstance(c, int) or not 0 <= c < cores}
        if bad:
            raise ValueError(
                f"partition assignment maps actors to {unit}s outside "
                f"[0, {cores}): {dict(sorted(bad.items()))}")
        for fifo, src, dst in self.delay_partition_constraints():
            if assignment[src] != assignment[dst]:
                spec = self.fifos[fifo]
                raise ValueError(
                    f"delay channel {fifo!r} ({src} -> {dst}, rate "
                    f"{spec.rate}, delay {spec.delay}) may not cross "
                    f"partitions ({unit}s {assignment[src]} vs "
                    f"{assignment[dst]}): its initial tokens do not "
                    "cover a whole read window (delay < rate), so the "
                    "Fig. 2 copy-back races the remote reader's phase-0 "
                    f"window; assign both endpoints to one {unit}")


def repetition_vector(network: Network) -> Dict[str, int]:
    """SDF balance equations (Lee & Messerschmitt) for this MoC.

    Both ports of a channel inherit the same rate r, so production ==
    consumption on every edge and the minimal repetition vector is all-ones
    for any *connected* network.  Disconnected components are independently
    all-ones too; we solve it generally anyway so the function stays honest
    if the MoC is ever relaxed (paper §5 names rate relaxation as the main
    future-work direction).
    """
    names = list(network.actors)
    idx = {n: i for i, n in enumerate(names)}
    n = len(names)
    # Union-find over equal-rate constraints q_src * r == q_dst * r  ->  q_src == q_dst.
    parent = list(range(n))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for e in network.edges:
        a, b = find(idx[e.src_actor]), find(idx[e.dst_actor])
        if a != b:
            parent[a] = b
    return {name: 1 for name in names}


def iteration_token_flops(network: Network) -> int:
    """Static per-iteration FLOP estimate from actor annotations (roofline)."""
    return int(sum(a.cost_flops for a in network.actors.values()))
