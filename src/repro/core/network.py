"""Actor networks ℵ = (A, F) — paper §2.2.

A network is a set of actors interconnected by FIFO channels.  Each channel
connects exactly one output port to exactly one input port (paper §3.2).
Ports inherit the token rate of the channel they connect to.

The builder validates the MoC's structural rules at construction time:
  * single writer / single reader per channel;
  * control channels have rate 1 and no delay token;
  * every declared port is connected exactly once;
  * dynamic actors have exactly one control port fed by a channel.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Tuple

import jax
import numpy as np

from repro.core.actor import ActorSpec
from repro.core.fifo import FifoSpec, FifoState, total_buffer_bytes


@dataclasses.dataclass(frozen=True)
class Edge:
    """One channel binding: (src actor, src port) --fifo--> (dst actor, dst port)."""

    fifo: str
    src_actor: str
    src_port: str
    dst_actor: str
    dst_port: str


class Network:
    """Validated actor network (immutable after construction)."""

    def __init__(self, actors: List[ActorSpec], fifos: List[FifoSpec], edges: List[Edge],
                 initial_tokens: Optional[Mapping[str, Any]] = None):
        self.actors: Dict[str, ActorSpec] = {a.name: a for a in actors}
        self.fifos: Dict[str, FifoSpec] = {f.name: f for f in fifos}
        self.edges: Tuple[Edge, ...] = tuple(edges)
        self.initial_tokens: Dict[str, Any] = dict(initial_tokens or {})
        if len(self.actors) != len(actors):
            raise ValueError("duplicate actor names")
        if len(self.fifos) != len(fifos):
            raise ValueError("duplicate fifo names")
        self._edge_by_fifo: Dict[str, Edge] = {}
        for e in self.edges:
            if e.fifo in self._edge_by_fifo:
                raise ValueError(f"fifo {e.fifo} bound to more than one edge "
                                 f"(channels connect exactly one output to one input)")
            self._edge_by_fifo[e.fifo] = e
        self._validate()
        # Port -> fifo lookup tables used by the executors.
        self.in_fifo: Dict[Tuple[str, str], str] = {
            (e.dst_actor, e.dst_port): e.fifo for e in self.edges
        }
        self.out_fifo: Dict[Tuple[str, str], str] = {
            (e.src_actor, e.src_port): e.fifo for e in self.edges
        }

    # ------------------------------------------------------------------ #
    def _validate(self) -> None:
        for e in self.edges:
            if e.fifo not in self.fifos:
                raise ValueError(f"edge references unknown fifo {e.fifo}")
            if e.src_actor not in self.actors:
                raise ValueError(f"edge references unknown actor {e.src_actor}")
            if e.dst_actor not in self.actors:
                raise ValueError(f"edge references unknown actor {e.dst_actor}")
            src = self.actors[e.src_actor]
            dst = self.actors[e.dst_actor]
            if e.src_port not in src.out_ports:
                raise ValueError(f"{e.src_actor} has no output port {e.src_port}")
            if e.dst_port not in dst.all_in_ports():
                raise ValueError(f"{e.dst_actor} has no input port {e.dst_port}")
            if e.dst_port == dst.control_port and not self.fifos[e.fifo].is_control:
                raise ValueError(
                    f"fifo {e.fifo} feeds control port {e.dst_actor}.{e.dst_port} "
                    f"but is not marked is_control (rate-1 rule, paper §2.2)"
                )
        # Exactly-once connectivity.
        seen_src, seen_dst = set(), set()
        for e in self.edges:
            k_src, k_dst = (e.src_actor, e.src_port), (e.dst_actor, e.dst_port)
            if k_src in seen_src:
                raise ValueError(f"output port {k_src} connected twice")
            if k_dst in seen_dst:
                raise ValueError(f"input port {k_dst} connected twice")
            seen_src.add(k_src)
            seen_dst.add(k_dst)
        for a in self.actors.values():
            for p in a.all_in_ports():
                if (a.name, p) not in seen_dst:
                    raise ValueError(f"input port {a.name}.{p} not connected")
            for p in a.out_ports:
                if (a.name, p) not in seen_src:
                    raise ValueError(f"output port {a.name}.{p} not connected")
        for f in self.fifos.values():
            if f.name not in self._edge_by_fifo:
                raise ValueError(f"fifo {f.name} not bound to any edge")
        for name, tok in self.initial_tokens.items():
            if name not in self.fifos:
                raise ValueError(f"initial token for unknown fifo {name}")
            if not self.fifos[name].delay:
                raise ValueError(f"initial token for delay-free fifo {name}")

    # ------------------------------------------------------------------ #
    def edge_of(self, fifo_name: str) -> Edge:
        return self._edge_by_fifo[fifo_name]

    def fifo_for_in_port(self, actor: str, port: str) -> FifoSpec:
        return self.fifos[self.in_fifo[(actor, port)]]

    def fifo_for_out_port(self, actor: str, port: str) -> FifoSpec:
        return self.fifos[self.out_fifo[(actor, port)]]

    def sources(self) -> List[str]:
        return [a.name for a in self.actors.values() if a.is_source]

    def sinks(self) -> List[str]:
        return [a.name for a in self.actors.values() if a.is_sink]

    def buffer_bytes(self) -> int:
        """Total communication-buffer memory — paper Table 1 accounting."""
        return total_buffer_bytes(self.fifos.values())

    # ------------------------------------------------------------------ #
    # State construction.                                                  #
    # ------------------------------------------------------------------ #
    def init_state(self) -> Dict[str, Any]:
        fifo_states: Dict[str, FifoState] = {}
        for name, spec in self.fifos.items():
            fifo_states[name] = spec.init_state(self.initial_tokens.get(name))
        actor_states = {name: a.init_state() for name, a in self.actors.items()}
        return {"fifos": fifo_states, "actors": actor_states}

    # ------------------------------------------------------------------ #
    # Graph utilities for the scheduler.                                   #
    # ------------------------------------------------------------------ #
    def precedence_edges(self, ignore_delay: bool = True) -> List[Tuple[str, str]]:
        """(producer, consumer) pairs for one-iteration scheduling.

        A delay token breaks producer->consumer precedence only when the
        initial tokens cover a whole read window, i.e. ``delay >= rate``.
        With the MoC's single delay token and r > 1, the first read still
        needs r-1 *fresh* tokens (paper Fig. 2: read 1 consumes slots
        0..r-1 = D plus write 1's prefix), so the producer keeps firing
        first and the delay merely shifts the data by one token.
        """
        out = []
        for e in self.edges:
            f = self.fifos[e.fifo]
            if ignore_delay and f.delay >= f.rate:
                continue
            out.append((e.src_actor, e.dst_actor))
        return out

    def topological_order(self) -> List[str]:
        """Topo sort with delay edges broken; raises on deadlock cycles.

        In this MoC every channel has the same rate at both ends, so the SDF
        repetition vector is all-ones and one *iteration* = one firing of
        every actor.  A cycle with no delay token can never fire — the
        classic dataflow deadlock — which we diagnose here at build time.
        """
        names = list(self.actors)
        idx = {n: i for i, n in enumerate(names)}
        n = len(names)
        adj = [[] for _ in range(n)]
        indeg = [0] * n
        for u, v in self.precedence_edges(ignore_delay=True):
            adj[idx[u]].append(idx[v])
            indeg[idx[v]] += 1
        order, stack = [], [i for i in range(n) if indeg[i] == 0]
        while stack:
            u = stack.pop()
            order.append(u)
            for v in adj[u]:
                indeg[v] -= 1
                if indeg[v] == 0:
                    stack.append(v)
        if len(order) != n:
            stuck = [names[i] for i in range(n) if indeg[i] > 0]
            raise ValueError(
                "network deadlock: cycle without an initial (delay) token "
                f"through actors {stuck} — paper §2.2 requires a delay token "
                "on feedback loops (IIR example)"
            )
        return [names[i] for i in order]

    def check_schedule_feasible(self) -> None:
        """Simulate one iteration of the single-appearance schedule with
        occupancy counters and verify Eq. 1 capacities are never exceeded
        and no read underflows (trace-time analogue of blocking semantics).
        """
        occ = {name: spec.delay for name, spec in self.fifos.items()}
        for actor in self.topological_order():
            a = self.actors[actor]
            for p in a.all_in_ports():
                f = self.fifo_for_in_port(actor, p)
                need = 1 if p == a.control_port else f.rate
                if occ[f.name] < need:
                    raise ValueError(
                        f"schedule infeasible: {actor}.{p} reads {need} from "
                        f"{f.name} holding {occ[f.name]}"
                    )
                occ[f.name] -= need
            for p in a.out_ports:
                f = self.fifo_for_out_port(actor, p)
                if occ[f.name] + f.rate > f.writable_occupancy_bound:
                    raise ValueError(
                        f"schedule infeasible: {actor}.{p} writes {f.rate} to "
                        f"{f.name} at {occ[f.name]}/{f.writable_occupancy_bound} "
                        f"— blocking bound violated (Eq. 1 phase pattern)"
                    )
                occ[f.name] += f.rate
        for name, spec in self.fifos.items():
            if occ[name] != spec.delay:
                raise ValueError(
                    f"unbalanced iteration: fifo {name} ends at occupancy "
                    f"{occ[name]} != initial {spec.delay}; single-appearance "
                    "schedule would grow without bound"
                )


def repetition_vector(network: Network) -> Dict[str, int]:
    """SDF balance equations (Lee & Messerschmitt) for this MoC.

    Both ports of a channel inherit the same rate r, so production ==
    consumption on every edge and the minimal repetition vector is all-ones
    for any *connected* network.  Disconnected components are independently
    all-ones too; we solve it generally anyway so the function stays honest
    if the MoC is ever relaxed (paper §5 names rate relaxation as the main
    future-work direction).
    """
    names = list(network.actors)
    idx = {n: i for i, n in enumerate(names)}
    n = len(names)
    # Union-find over equal-rate constraints q_src * r == q_dst * r  ->  q_src == q_dst.
    parent = list(range(n))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for e in network.edges:
        a, b = find(idx[e.src_actor]), find(idx[e.dst_actor])
        if a != b:
            parent[a] = b
    return {name: 1 for name in names}


def iteration_token_flops(network: Network) -> int:
    """Static per-iteration FLOP estimate from actor annotations (roofline)."""
    return int(sum(a.cost_flops for a in network.actors.values()))
