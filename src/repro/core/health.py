"""Runtime health layer: in-kernel fault flags and host-side diagnostics.

The paper's pitch is that dynamic data-dependent rates are *safe* to run
on accelerators — but the runtime as shipped trusted that promise: a
producer pushed past its Eq. 1 ring capacity silently corrupts bytes, a
corrupted cursor silently wraps, and a livelocked network exhausts
``max_sweeps`` returning partial state indistinguishable from quiescence.
PRUNE (arXiv:1802.06625) frames the fix as two-sided: prove buffer bounds
at build time where decidable (``NetworkBuilder.build(check_bounds=True)``,
see :mod:`repro.core.builder`), and detect violations at run time with
*named* diagnostics everywhere else.  This module is the run-time side:

  * a packed per-channel **fault word** (:data:`OVERFLOW`,
    :data:`UNDERFLOW`, :data:`CURSOR_INVALID`, :data:`NONFINITE`,
    :data:`STALL`, :data:`DOMAIN` — values outside a channel's declared
    ``FifoSpec.domain``, the integer-channel analogue of NONFINITE that
    the serving graph uses to catch poisoned request rows) plus
    per-channel **high-water occupancy marks**,
    carried as extra loop state through the dynamic executor's sweep loop
    and the megakernel's in-kernel ``while_loop`` (:class:`HealthState`);
  * the pure guard-bit predicates the executors evaluate next to every
    channel operation (:func:`read_guard_bits` / :func:`write_guard_bits`).
    The guards recompute the **true** occupancy from the monotonic rd/wr
    cursors — ``delay + (wr - rd) * rate`` — so occupancy-counter
    corruption is itself detectable, not trusted;
  * the host-side decode into :class:`Diagnostics` /
    :class:`NetworkFaultError` naming the offending channel and its
    endpoint actors, and the stall forensics (:func:`diagnose_stall`)
    naming which actor starved on which full/empty channel when the sweep
    loop exits via the ``max_sweeps`` bound instead of quiescence.

Guards are **off by default** (``ExecutionPlan(guards=True)`` opts in):
with guards off the executors are bit-identical to the pre-health-layer
kernels, and with guards on a clean run's states, cursors, fire counts
and sweeps are still bit-identical — the guard arithmetic only *observes*
the channel operations, it never changes them (faulty operations proceed
and are reported, the guards detect rather than mask).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ----------------------------------------------------------------------- #
# The packed fault word.  One int32 per channel, bits OR-accumulated over
# the run; STALL is a run-level condition (no single channel owns it) and
# appears only in the host-side decode.
# ----------------------------------------------------------------------- #
OVERFLOW = 1        # enabled write past the Eq. 1 writable occupancy bound
UNDERFLOW = 2       # enabled read from a channel with < rate true tokens
CURSOR_INVALID = 4  # occ counter disagrees with delay + (wr - rd) * rate
NONFINITE = 8       # NaN/Inf in an enabled window (float channels only)
STALL = 16          # sweep loop exhausted max_sweeps with work remaining
DOMAIN = 32         # enabled window outside the channel's declared domain

FAULT_NAMES = {
    OVERFLOW: "OVERFLOW",
    UNDERFLOW: "UNDERFLOW",
    CURSOR_INVALID: "CURSOR_INVALID",
    NONFINITE: "NONFINITE",
    STALL: "STALL",
    DOMAIN: "DOMAIN",
}


def fault_names(bits: int) -> Tuple[str, ...]:
    """Decode a packed fault word into its set-bit names."""
    return tuple(name for bit, name in sorted(FAULT_NAMES.items())
                 if bits & bit)


# ----------------------------------------------------------------------- #
# Guard-bit predicates — pure jnp, shared verbatim by the host executor
# (on FifoState scalars) and the megakernel (on cursor-block scalars).
# ----------------------------------------------------------------------- #
def true_occupancy(spec, rd: jax.Array, wr: jax.Array) -> jax.Array:
    """Occupancy recomputed from the monotonic cursors alone.

    Every read advances ``rd`` by 1 (consuming ``rate`` tokens), every
    write advances ``wr`` by 1 (producing ``rate``), and ``delay`` initial
    tokens precede both — so ``delay + (wr - rd) * rate`` is the ground
    truth the ``occ`` counter must agree with.  Trusting ``occ`` itself
    would blind the guards to exactly the corruption they exist to catch.
    """
    return jnp.int32(spec.delay) + (wr - rd) * jnp.int32(spec.rate)


def _nonfinite_bit(spec, values: jax.Array, enabled: jax.Array) -> jax.Array:
    if not jnp.issubdtype(jnp.dtype(spec.dtype), jnp.inexact):
        return jnp.int32(0)  # integer channels cannot carry NaN/Inf
    bad = jnp.logical_not(jnp.all(jnp.isfinite(values)))
    return jnp.where(jnp.logical_and(enabled, bad),
                     jnp.int32(NONFINITE), jnp.int32(0))


def _domain_bit(spec, values: jax.Array, enabled: jax.Array) -> jax.Array:
    """DOMAIN fault of one enabled window against the spec's declared
    value domain — the integer-channel analogue of NONFINITE (NaN
    comparisons are False, so non-finite floats fall to that guard, not
    this one).  Channels without a declared domain contribute nothing,
    keeping the guards-on HLO of undeclared networks unchanged."""
    if getattr(spec, "domain", None) is None:
        return jnp.int32(0)
    lo, hi = spec.domain
    lo = jnp.asarray(lo, values.dtype)
    hi = jnp.asarray(hi, values.dtype)
    bad = jnp.logical_not(jnp.all(jnp.logical_and(values >= lo,
                                                  values <= hi)))
    return jnp.where(jnp.logical_and(enabled, bad),
                     jnp.int32(DOMAIN), jnp.int32(0))


def read_guard_bits(spec, rd: jax.Array, wr: jax.Array, occ: jax.Array,
                    enabled: jax.Array, window: jax.Array) -> jax.Array:
    """Fault bits of one (possibly masked) read, from the pre-op state.

    ``enabled`` gates UNDERFLOW and NONFINITE (a disabled port's stale
    window is unspecified by the MoC); CURSOR_INVALID is unconditional —
    the consistency invariant must hold whether or not this visit fires.
    """
    true_occ = true_occupancy(spec, rd, wr)
    bits = jnp.where(occ != true_occ, jnp.int32(CURSOR_INVALID), jnp.int32(0))
    starved = true_occ < spec.rate
    bits = bits | jnp.where(jnp.logical_and(enabled, starved),
                            jnp.int32(UNDERFLOW), jnp.int32(0))
    return (bits | _nonfinite_bit(spec, window, enabled)
            | _domain_bit(spec, window, enabled))


def write_guard_bits(spec, rd: jax.Array, wr: jax.Array, occ: jax.Array,
                     enabled: jax.Array, tokens: jax.Array) -> jax.Array:
    """Fault bits of one (possibly masked) write, from the pre-op state."""
    true_occ = true_occupancy(spec, rd, wr)
    bits = jnp.where(occ != true_occ, jnp.int32(CURSOR_INVALID), jnp.int32(0))
    over = true_occ + spec.rate > spec.writable_occupancy_bound
    bits = bits | jnp.where(jnp.logical_and(enabled, over),
                            jnp.int32(OVERFLOW), jnp.int32(0))
    return (bits | _nonfinite_bit(spec, tokens, enabled)
            | _domain_bit(spec, tokens, enabled))


# ----------------------------------------------------------------------- #
# The loop-carried health state.
# ----------------------------------------------------------------------- #
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class HealthState:
    """Per-channel fault words + high-water marks, threaded as loop state.

    ``fault[i]`` is the OR of every guard-bit word channel ``i`` produced
    during the run; ``high_water[i]`` the maximum *true* occupancy any
    enabled write reached (so an overflow's magnitude is visible even when
    the ``occ`` counter itself was the corrupted quantity).
    """

    fault: jax.Array        # (n_fifos,) int32 bitmask
    high_water: jax.Array   # (n_fifos,) int32

    def record(self, fi: int, bits: jax.Array) -> "HealthState":
        return HealthState(
            fault=self.fault.at[fi].set(jnp.bitwise_or(self.fault[fi], bits)),
            high_water=self.high_water)

    def mark_high_water(self, fi: int, occupancy: jax.Array) -> "HealthState":
        return HealthState(fault=self.fault,
                           high_water=self.high_water.at[fi].max(occupancy))


def init_health(n_fifos: int) -> HealthState:
    return HealthState(fault=jnp.zeros((n_fifos,), jnp.int32),
                       high_water=jnp.zeros((n_fifos,), jnp.int32))


# ----------------------------------------------------------------------- #
# Host-side decode.
# ----------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ChannelFault:
    """One faulting channel, named end to end."""

    fifo: str
    src_actor: str
    src_port: str
    dst_actor: str
    dst_port: str
    bits: int
    faults: Tuple[str, ...]
    high_water: int
    occupancy_bound: int

    def describe(self) -> str:
        return (f"channel {self.fifo!r} ({self.src_actor}.{self.src_port} -> "
                f"{self.dst_actor}.{self.dst_port}): "
                f"{', '.join(self.faults)} "
                f"[high-water {self.high_water} / bound "
                f"{self.occupancy_bound}]")


@dataclasses.dataclass(frozen=True)
class StallReport:
    """Forensics of a ``max_sweeps`` exhaustion.

    ``blocked`` pairs each non-fireable actor with the first blocking
    condition (starved on an empty channel / blocked on a full one /
    closed ready gate); ``runnable`` lists actors that could still fire —
    under exhaustion the network was mid-flight, under a genuine livelock
    both tell which side of a cycle starved.  ``occupancy`` is the final
    per-channel occupancy snapshot.
    """

    runnable: Tuple[str, ...]
    blocked: Tuple[Tuple[str, str], ...]
    occupancy: Dict[str, int]

    def describe(self) -> str:
        parts = [f"{a}: {why}" for a, why in self.blocked]
        if self.runnable:
            parts.append(f"still runnable: {', '.join(self.runnable)}")
        return "; ".join(parts) if parts else "no actors blocked"


@dataclasses.dataclass(frozen=True)
class Diagnostics:
    """Host-decoded health of one run (``RunResult.diagnostics``)."""

    ok: bool
    stalled: bool
    faults: Tuple[ChannelFault, ...]
    high_water: Dict[str, int]
    stall: Optional[StallReport] = None

    def summary(self) -> str:
        if self.ok:
            return "healthy"
        parts = [f.describe() for f in self.faults]
        if self.stalled:
            msg = "STALL: sweep budget exhausted with work remaining"
            if self.stall is not None:
                msg += f" ({self.stall.describe()})"
            parts.append(msg)
        return "; ".join(parts)


class NetworkFaultError(RuntimeError):
    """A guarded run tripped at least one fault flag (or stalled).

    Carries the full :class:`Diagnostics` as ``.diagnostics``; the
    message names the offending channel(s) and their endpoint actors.
    """

    def __init__(self, diagnostics: Diagnostics):
        self.diagnostics = diagnostics
        super().__init__(f"network fault: {diagnostics.summary()}")


def decode_health(network, health: Optional[HealthState], stalled: bool,
                  state=None) -> Diagnostics:
    """Decode device-side health arrays into named host diagnostics.

    ``network`` is the executed :class:`repro.core.network.Network` (its
    fifo declaration order indexes the health vectors); ``state`` (the
    final NetworkState), when given, feeds the stall forensics.  With
    ``health=None`` (a guards-off run) only the stall condition is
    decoded — fault words and high-water marks were never collected.
    """
    names = list(network.fifos)
    if health is None:
        fault = np.zeros((len(names),), np.int32)
        hw = np.zeros((len(names),), np.int32)
    else:
        fault = np.asarray(health.fault)
        hw = np.asarray(health.high_water)
    faults = []
    for i, name in enumerate(names):
        bits = int(fault[i])
        if not bits:
            continue
        spec = network.fifos[name]
        e = network.edge_of(name)
        faults.append(ChannelFault(
            fifo=name, src_actor=e.src_actor, src_port=e.src_port,
            dst_actor=e.dst_actor, dst_port=e.dst_port, bits=bits,
            faults=fault_names(bits), high_water=int(hw[i]),
            occupancy_bound=spec.writable_occupancy_bound))
    stall = (diagnose_stall(network, state)
             if stalled and state is not None else None)
    high_water = ({} if health is None
                  else {name: int(hw[i]) for i, name in enumerate(names)})
    return Diagnostics(ok=not faults and not stalled, stalled=bool(stalled),
                       faults=tuple(faults), high_water=high_water,
                       stall=stall)


def diagnose_stall(network, state) -> StallReport:
    """Eager per-actor blocking analysis of a final state.

    Mirrors ``executor._can_fire`` with concrete values: peek the control
    token where one is available, evaluate the rates, and name the first
    blocking condition per non-fireable actor — the forensic snapshot the
    ``max_sweeps`` exhaustion path attaches to its warning/error instead
    of returning partial state silently.
    """
    from repro.core.network import NetworkState  # local: avoid import cycle
    if not isinstance(state, NetworkState):
        state = network.state_from_dict(state)
    occupancy = {name: int(state.fifos[i].occ)
                 for name, i in network.fifo_index.items()}
    runnable, blocked = [], []
    for name, a in network.actors.items():
        reason = None
        if a.ready is not None and not bool(
                a.ready(state.actors[network.actor_index[name]])):
            reason = "ready() gate closed (source feed exhausted?)"
        rates = None
        ctl = network.control_specs[name]
        if reason is None:
            if ctl is not None:
                cspec, ci = ctl
                if int(state.fifos[ci].occ) < 1:
                    reason = (f"starved on empty control channel "
                              f"{cspec.name!r}")
                else:
                    tok = cspec.peek(state.fifos[ci])
                    rates = {p: int(v) for p, v in a.rates_for(tok).items()}
            else:
                rates = {p: int(v) for p, v in a.rates_for(None).items()}
        if reason is None:
            for p, spec, fi in network.in_port_specs[name]:
                if rates[p] and int(state.fifos[fi].occ) < spec.rate:
                    reason = (f"starved on empty channel {spec.name!r} "
                              f"(occupancy {int(state.fifos[fi].occ)}, "
                              f"needs {spec.rate})")
                    break
        if reason is None:
            for p, spec, fi in network.out_port_specs[name]:
                o = int(state.fifos[fi].occ)
                if rates[p] and o + spec.rate > spec.writable_occupancy_bound:
                    reason = (f"blocked on full channel {spec.name!r} "
                              f"(occupancy {o} / bound "
                              f"{spec.writable_occupancy_bound})")
                    break
        if reason is None:
            runnable.append(name)
        else:
            blocked.append((name, reason))
    return StallReport(runnable=tuple(runnable), blocked=tuple(blocked),
                       occupancy=occupancy)
