"""End-to-end LM training driver: data pipeline -> sharded train step ->
fault-tolerant trainer with checkpointing.

Presets:
  --preset tiny   (default in this CPU container: ~3M params, 200 steps,
                   finishes in minutes; loss visibly drops)
  --preset 100m   (the deliverable config: ~110M-param llama-style model,
                   300 steps — sized for a real accelerator; runs here too,
                   just slowly)

    PYTHONPATH=src python examples/train_lm.py --preset tiny
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.data import DataConfig, SyntheticLM
from repro.models import init_params
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.train import (Trainer, TrainerConfig, TrainOptions, make_train_step)

PRESETS = {
    "tiny": dict(n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_ff=512,
                 vocab=2048, seq=128, batch=8, steps=200, lr=1e-3),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=2048, vocab=32000, seq=1024, batch=32, steps=300,
                 lr=3e-4),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    p = PRESETS[args.preset]

    cfg = ArchConfig(name=f"lm-{args.preset}", family="dense",
                     n_layers=p["n_layers"], d_model=p["d_model"],
                     n_heads=p["n_heads"], n_kv_heads=p["n_kv_heads"],
                     d_ff=p["d_ff"], vocab=p["vocab"],
                     head_dim=p["d_model"] // p["n_heads"])
    print(f"model: {cfg.param_count()/1e6:.1f} M params")

    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=p["seq"],
                                  global_batch=p["batch"]))
    opt_cfg = AdamWConfig(lr=p["lr"], warmup_steps=20,
                          total_steps=p["steps"])
    step = jax.jit(make_train_step(cfg, opt_cfg, TrainOptions()),
                   donate_argnums=(0, 1))

    key = jax.random.PRNGKey(0)

    def init_state():
        params = init_params(key, cfg)
        return {"params": params, "opt": init_opt_state(params)}

    trainer = Trainer(
        TrainerConfig(total_steps=p["steps"], checkpoint_every=50,
                      checkpoint_dir=args.ckpt_dir, log_every=20),
        step, data, init_state,
        to_device=lambda b: {k: jnp.asarray(v) for k, v in b.items()})
    trainer.run()
    hist = trainer.metrics_history
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"over {p['steps']} steps")
    assert hist[-1]["loss"] < hist[0]["loss"], "loss must decrease"
    print("OK")


if __name__ == "__main__":
    main()
