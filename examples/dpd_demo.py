"""Dynamic Predistortion demo (paper §4.2, Fig. 5): the Configuration
actor reconfigures the active filter set at run time; dynamic data rates
let the compiled path skip disabled Poly branches — the paper's headline
up-to-5x win, measured here directly.

    PYTHONPATH=src python examples/dpd_demo.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.dpd import build_dpd


def throughput(net, n_firings, block_l):
    prog = net.compile(mode="static", n_iterations=n_firings)
    prog.run()                                       # warmup
    t0 = time.perf_counter()
    state = prog.run().state
    jax.block_until_ready(state.actor("sink")[0])
    dt = time.perf_counter() - t0
    return n_firings * block_l / dt / 1e6


def main():
    n_firings, L = 8, 32768
    rng = np.random.default_rng(0)
    sig = jnp.asarray(rng.normal(size=(2, n_firings * L)), jnp.float32)

    static_net = build_dpd(n_firings, block_l=L, signal=sig,
                           static_all_active=True)
    ms_static = throughput(static_net, n_firings, L)
    print(f"static (all 10 branches, DAL-style): {ms_static:7.1f} Msamples/s")

    for n_active in (2, 5, 10):
        sched = np.full(n_firings, n_active, np.int32)
        net = build_dpd(n_firings, active_schedule=sched, block_l=L, signal=sig)
        ms = throughput(net, n_firings, L)
        print(f"dynamic rates, {n_active:2d} active branches:   "
              f"{ms:7.1f} Msamples/s  ({ms/ms_static:4.1f}x vs static)")
    print("paper claim: dynamic data rates on the accelerator -> up to 5x.")


if __name__ == "__main__":
    main()
