"""Motion Detection demo (paper §4.1, Fig. 4): synthesizes a moving-square
video, runs the 5-actor network (compiled, token rate 4), reports fps and
the detected motion statistics.

    PYTHONPATH=src python examples/motion_detection_demo.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.motion_detection import build_motion_detection


def moving_square_video(n=32, h=240, w=320, size=30):
    rng = np.random.default_rng(0)
    base = rng.uniform(90, 110, (h, w)).astype(np.float32)
    frames = []
    for t in range(n):
        f = base.copy()
        x = 20 + 7 * t
        f[80:80 + size, x:x + size] = 250.0
        frames.append(f)
    return np.stack(frames)


def main():
    video = moving_square_video()
    n = len(video)
    net = build_motion_detection(n, rate=4, video=jnp.asarray(video))
    print(f"network: {list(net.actors)}  buffers: "
          f"{net.buffer_bytes()/1e6:.2f} MB (paper Table 1: 3.46)")
    prog = net.compile(mode="static", n_iterations=n // 4)
    prog.run()                                       # warmup+compile
    t0 = time.perf_counter()
    state = prog.run().state
    jax.block_until_ready(state.actor("sink")[0])
    dt = time.perf_counter() - t0
    motion = np.asarray(prog.collect("sink", state))
    frac = (motion > 0).mean(axis=(1, 2))
    print(f"throughput: {n/dt:.0f} fps (compiled, rate 4)")
    print(f"motion fraction per frame (first 8): {np.round(frac[:8], 4)}")
    assert frac[1:].max() > 0.001, "moving square must be detected"
    print("OK")


if __name__ == "__main__":
    main()
