"""Quickstart: the paper's MoC in ~60 lines.

Builds a tiny dynamic-data-rate network — a control actor gates an
amplifier actor (token rate 0 or r per firing) — compiles it into one XLA
program, and shows the rate-0 firings genuinely skipping work.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Edge, FifoSpec, Network, collect_sink,
                        compile_dynamic, dynamic_actor, map_fire,
                        static_actor)

N_FIRINGS, RATE, TOK = 8, 2, (4,)


def main():
    # Source: emits windows of RATE tokens from a staged array.
    def src_fire(state, inputs, rates):
        data, idx = state
        win = jax.lax.dynamic_slice_in_dim(data, idx * RATE, RATE, axis=0)
        return (data, idx + 1), {"out": win}

    n_enabled = (N_FIRINGS + 1) // 2
    source = static_actor(
        "source", (), ("out",), src_fire,
        init=lambda: (jnp.arange(N_FIRINGS * RATE * 4, dtype=jnp.float32)
                      .reshape(N_FIRINGS * RATE, 4), jnp.int32(0)),
        ready=lambda st: st[1] < n_enabled)

    # Control actor: enables the amplifier on every second firing.
    def ctl_fire(state, inputs, rates):
        return state + 1, {"out": (state % 2 == 0).astype(jnp.int32).reshape(1)}

    control = static_actor("control", (), ("out",), ctl_fire,
                           init=lambda: jnp.int32(0),
                           ready=lambda st: st < N_FIRINGS)

    # Dynamic actor: the control token pins its ports to rate 0 or RATE.
    amp = dynamic_actor(
        "amp", "c", lambda tok: {"in": tok[0] > 0, "out": tok[0] > 0},
        ("in",), ("out",), map_fire(lambda w: 10.0 * w, "in", "out"))

    def sink_fire(state, inputs, rates):
        data, idx = state
        return (jax.lax.dynamic_update_slice_in_dim(
            data, inputs["in"], idx * RATE, axis=0), idx + 1), {}

    sink = static_actor(
        "sink", ("in",), (), sink_fire,
        init=lambda: (jnp.zeros((N_FIRINGS * RATE, 4), jnp.float32),
                      jnp.int32(0)),
        finish=lambda st: st[0])

    net = Network(
        [source, control, amp, sink],
        [FifoSpec("f_c", 1, (1,), jnp.int32, is_control=True),
         FifoSpec("f_in", RATE, TOK),        # Eq. 1: capacity 2r (double buffer)
         FifoSpec("f_out", RATE, TOK)],
        [Edge("f_c", "control", "out", "amp", "c"),
         Edge("f_in", "source", "out", "amp", "in"),
         Edge("f_out", "amp", "out", "sink", "in")])

    print("channel capacities (Eq. 1):",
          {f.name: f.capacity_tokens for f in net.fifos.values()})
    run = compile_dynamic(net)                     # one XLA program
    state, counts = run(net.init_state())
    out = np.asarray(collect_sink(net, state, "sink"))
    print("firings:", {k: int(v) for k, v in counts.items()})
    print("first enabled window (x10):", out[0:RATE, 0])
    assert np.allclose(out[0:RATE], 10.0 * np.arange(RATE * 4).reshape(RATE, 4))
    print("OK — dynamic data rates on the compiled path.")


if __name__ == "__main__":
    main()
