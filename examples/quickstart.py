"""Quickstart: the paper's MoC in ~60 lines.

Builds a tiny dynamic-data-rate network with the declarative
``NetworkBuilder`` — a control actor gates an amplifier actor (token rate
0 or r per firing) — compiles it under an ``ExecutionPlan``, and shows
the rate-0 firings genuinely skipping work.

    PYTHONPATH=src python examples/quickstart.py
"""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ExecutionPlan, Mode, NetworkBuilder, dynamic_actor,
                        map_fire, static_actor)

N_FIRINGS, RATE, TOK = 8, 2, (4,)


def main():
    # Source: emits windows of RATE tokens from a staged array.
    def src_fire(state, inputs, rates):
        data, idx = state
        win = jax.lax.dynamic_slice_in_dim(data, idx * RATE, RATE, axis=0)
        return (data, idx + 1), {"out": win}

    n_enabled = (N_FIRINGS + 1) // 2
    source = static_actor(
        "source", (), ("out",), src_fire,
        init=lambda: (jnp.arange(N_FIRINGS * RATE * 4, dtype=jnp.float32)
                      .reshape(N_FIRINGS * RATE, 4), jnp.int32(0)),
        ready=lambda st: st[1] < n_enabled)

    # Control actor: enables the amplifier on every second firing.
    def ctl_fire(state, inputs, rates):
        return state + 1, {"out": (state % 2 == 0).astype(jnp.int32).reshape(1)}

    control = static_actor("control", (), ("out",), ctl_fire,
                           init=lambda: jnp.int32(0),
                           ready=lambda st: st < N_FIRINGS)

    # Dynamic actor: the control token pins its ports to rate 0 or RATE.
    amp = dynamic_actor(
        "amp", "c", lambda tok: {"in": tok[0] > 0, "out": tok[0] > 0},
        ("in",), ("out",), map_fire(lambda w: 10.0 * w, "in", "out"))

    def sink_fire(state, inputs, rates):
        data, idx = state
        return (jax.lax.dynamic_update_slice_in_dim(
            data, inputs["in"], idx * RATE, axis=0), idx + 1), {}

    sink = static_actor(
        "sink", ("in",), (), sink_fire,
        init=lambda: (jnp.zeros((N_FIRINGS * RATE, 4), jnp.float32),
                      jnp.int32(0)),
        finish=lambda st: st[0])

    # Declarative wiring: one connect() per channel; the control channel is
    # inferred from amp's control port, Eq. 1 capacities are derived.
    b = NetworkBuilder()
    b.actors(source, control, amp, sink)
    b.connect("control.out", "amp.c")                        # control (1,) i32
    b.connect("source.out", "amp.in", rate=RATE, token_shape=TOK)
    b.connect("amp.out", "sink.in", rate=RATE, token_shape=TOK)
    net = b.build()

    print("channel capacities (Eq. 1):",
          {f.name: f.capacity_tokens for f in net.fifos.values()})
    print("--- Graphviz (net.to_dot(), paste into any dot viewer) ---")
    print(net.to_dot())

    prog = net.compile(ExecutionPlan(mode="dynamic"))  # one XLA program
    result = prog.run()
    out = np.asarray(prog.collect("sink"))
    print("firings:", {k: int(v) for k, v in result.fire_counts.items()},
          f"in {int(result.sweeps)} sweeps")
    print("first enabled window (x10):", out[0:RATE, 0])
    assert np.allclose(out[0:RATE], 10.0 * np.arange(RATE * 4).reshape(RATE, 4))
    print("OK — dynamic data rates on the compiled path.")

    # Same network as ONE persistent Pallas kernel: buffered ring
    # buffers live in kernel scratch, the token-driven sweep loop runs
    # on the device (interpret mode off-TPU).  Bit-identical to the
    # dynamic executor — and transient channels (provably drained every
    # iteration) are FORWARDED as loop-carried windows instead of
    # scratch rings: the scratch diet, visible in the stats.
    mega = net.compile(ExecutionPlan(mode=Mode.MEGAKERNEL))
    mresult = mega.run()
    stats = mega.stats()
    assert np.array_equal(np.asarray(mega.collect("sink")), out)
    print(f"megakernel: {int(mresult.sweeps)} sweeps on-device, "
          f"{stats.scratch_bytes} B scratch vs "
          f"{stats.hbm_state_bytes} B HBM state")
    print(f"  transient forwarding: {len(stats.forwarded_fifos)} of "
          f"{stats.n_fifos} channels -> loop-carried windows, "
          f"{stats.reclaimed_scratch_bytes} B of rings reclaimed "
          f"({', '.join(stats.forwarded_fifos)})")

    # And grid-parallel: the firing table split across 2 cores (paper
    # §3.3 actor-to-core mapping), partition-crossing channels guarded
    # by shared cursor semaphores.  Still bit-identical — for any core
    # count.
    grid = net.compile(ExecutionPlan(mode=Mode.MEGAKERNEL, cores=2))
    gresult = grid.run()
    gstats = grid.stats()
    assert np.array_equal(np.asarray(grid.collect("sink")), out)
    print(f"grid x2: partitions {gstats.partition_actors}, "
          f"{int(gresult.sweeps)} rounds, "
          f"{gstats.shared_scratch_bytes} B shared rings+semaphores "
          f"({gstats.cut_objective} cut), per-core cursor rows "
          f"{gstats.core_cursor_rows} + {len(gstats.shared_fifos)} shared")

    # Observability: trace=True records every firing attempt (actor,
    # sweep, fired/skipped, per-channel occupancy) into a device-side
    # ring — bit-identical results, and the decoded Trace exports
    # Chrome trace-event JSON for https://ui.perfetto.dev plus a
    # measured Profile that can drive the partition cut.
    import tempfile
    traced = net.compile(ExecutionPlan(mode="dynamic", trace=True))
    tresult = traced.run()
    trace = tresult.trace
    assert trace.firing_counts() == {k: int(v)
                                     for k, v in tresult.fire_counts.items()}
    with tempfile.NamedTemporaryFile(suffix=".trace.json",
                                     delete=False) as f:
        trace.to_perfetto(f.name)
    prof = trace.profile()
    print(f"trace: {trace.n_events} events ({trace.dropped} dropped), "
          f"perfetto JSON -> {f.name}")
    print("  measured cut weights:",
          {k: v for k, v in sorted(prof.as_cut_weights()['actors'].items())})
    pgrid = net.compile(ExecutionPlan(mode=Mode.MEGAKERNEL, cores=2,
                                      cut_objective="profile", profile=prof))
    assert np.array_equal(np.asarray(pgrid.collect("sink", pgrid.run().state)),
                          out)
    print(f"  profile-driven grid x2 cut: {pgrid.stats().partition_actors} "
          f"(still bit-identical)")

    # Note on donation: ExecutionPlan.donate defaults to "auto" — donate
    # only when the ring-buffered bytes are small enough that copy
    # elision wins (full-size motion detection measured 1.7x SLOWER
    # donated; EXPERIMENTS.md §Executor perf).  Pass donate=True/False to
    # override per run.

    # Multi-device sharding: ExecutionPlan(devices=k) splits the firing
    # table across a 1-D mesh and lowers the crossing channels to
    # collective exchanges at each sweep barrier — bit-identical states
    # and fire counts at any k.  A plain run has one CPU device, so the
    # demo re-execs itself with a forced 8-device host platform (the CI
    # recipe); on real multi-chip hosts the flag is unnecessary.
    if jax.device_count() >= 2:
        sharded = net.compile(ExecutionPlan(mode="dynamic", devices=2))
        sresult = sharded.run()
        assert np.array_equal(
            np.asarray(sharded.collect("sink", sresult.state)), out)
        sstats = sharded.stats()
        print(f"sharded x{sstats.devices}: "
              f"{int(sresult.sweeps)} barrier rounds, "
              f"{sstats.collective_bytes_per_sweep} B/round collective, "
              f"partition {sstats.device_partition_actors} "
              "(still bit-identical)")
    else:
        import subprocess
        import sys
        env = dict(os.environ,
                   XLA_FLAGS="--xla_force_host_platform_device_count=8")
        print("sharded x2: one visible device here — re-running under "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8 ...")
        sub = subprocess.run([sys.executable, os.path.abspath(__file__)],
                             env=env, capture_output=True, text=True)
        print("\n".join(ln for ln in sub.stdout.splitlines()
                        if ln.startswith("sharded")) or sub.stderr[-500:])


if __name__ == "__main__":
    main()
