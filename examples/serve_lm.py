"""Continuous-batching serving demo: the admission/decode/retire actor
network vs the legacy fixed-batch engine, on one request set.

The actor engine (``repro.serve.ActorEngine``) runs the serving loop as
a dynamic-data-rate actor network: an admission actor feeds 0..k
requests per step from the (Poisson) arrival queue into free batch
slots, the decode actor fires one ``decode_step`` per step over the
live slots (a step with no live slot is a rate-0 firing — the control
token is consumed, the model body is skipped), and a slot is re-admitted
the moment its request retires.  Greedy tokens are identical
token-for-token to the fixed-batch engine; only the step count — and so
the sustained tok/s and completion latency — differs.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import smoke_config
from repro.graphs.serving import poisson_trace
from repro.models import init_params
from repro.serve import ActorEngine, Engine, Request, ServeConfig


def main():
    cfg = smoke_config("granite-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(batch_size=4, max_prompt=32, max_new=16)

    rng = np.random.default_rng(0)
    # Variable prompt lengths AND variable budgets: the adaptive workload
    # where fixed batches strand idle slots on the short requests.
    lens = [5, 12, 31, 8, 20, 3, 17]
    requests = [
        Request(prompt=rng.integers(0, cfg.vocab, n).astype(np.int32),
                max_new=16 if i % 2 == 0 else 3)
        for i, n in enumerate(lens)
    ]
    arrivals = poisson_trace(len(requests), rate=1.5, seed=3)
    n_tok = sum(min(r.max_new, scfg.max_new) for r in requests)

    legacy = Engine(cfg, params, scfg)
    t0 = time.perf_counter()
    ref = legacy.generate(requests)
    dt_legacy = time.perf_counter() - t0

    actor = ActorEngine(cfg, params, scfg)   # plan=ExecutionPlan("dynamic")
    t0 = time.perf_counter()
    out = actor.generate(requests, arrivals=arrivals)
    dt_actor = time.perf_counter() - t0

    for a, b in zip(ref, out):               # the bit-identity oracle
        np.testing.assert_array_equal(a.tokens, b.tokens)

    print(f"legacy fixed-batch: {n_tok} tokens in {dt_legacy:.2f}s "
          f"({n_tok / dt_legacy:.0f} tok/s incl. compile)")
    print(f"actor continuous:   {n_tok} tokens in {dt_actor:.2f}s "
          f"({n_tok / dt_actor:.0f} tok/s incl. compile), "
          f"{actor.last_fire_counts['decode']} decode firings over "
          f"{actor.last_sweeps} sweeps")
    lat = actor.last_latency_steps
    print(f"completion latency: p50 {np.percentile(lat, 50):.0f} / "
          f"p99 {np.percentile(lat, 99):.0f} steps (open-loop arrivals)")
    for i, r in enumerate(out[:3]):
        print(f"req {i} (prompt {r.prompt_len} toks) ->", r.tokens[:8], "...")
    print("tokens identical to legacy engine: OK")


if __name__ == "__main__":
    main()
