"""Batched serving demo: prefill + greedy decode over request batches
through the serving engine (ring KV caches = the paper's delay-token
feedback FIFOs).

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models import init_params
from repro.serve import Engine, Request, ServeConfig


def main():
    cfg = smoke_config("granite-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(batch_size=4, max_prompt=32, max_new=16)
    engine = Engine(cfg, params, scfg)

    rng = np.random.default_rng(0)
    requests = [
        Request(prompt=rng.integers(0, cfg.vocab, n).astype(np.int32),
                max_new=16)
        for n in [5, 12, 31, 8, 20, 3, 17]
    ]
    t0 = time.perf_counter()
    results = engine.generate(requests)
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.tokens) for r in results)
    print(f"served {len(requests)} requests in {len(requests)//scfg.batch_size+1} "
          f"batches: {n_tok} tokens in {dt:.2f}s ({n_tok/dt:.0f} tok/s incl. compile)")
    for i, r in enumerate(results[:3]):
        print(f"req {i} (prompt {r.prompt_len} toks) ->", r.tokens[:8], "...")
    print("OK")


if __name__ == "__main__":
    main()
